"""One traced put, end to end — the persistence waterfall, annotated.

Runs a 3-node synced nezha cluster, traces a single put, and prints:

  1. the cross-node span waterfall (client -> leader append+fsync ->
     follower appends+fsyncs -> apply -> client ack), on virtual time;
  2. the causality audit verdict (durable-before-ack, quorum-before-
     commit, commit-before-apply, apply-before-client-ack);
  3. the per-layer byte bill for the put, reconciled against Metrics;
  4. a few lines of the Prometheus-style exposition the same run feeds.

  PYTHONPATH=src python examples/trace_put.py
"""
import tempfile

from repro.core import trace
from repro.core.cluster import Cluster

wd = tempfile.mkdtemp(prefix="trace_put_")
c = Cluster(n=3, engine="nezha", workdir=wd, seed=7, sync=True,
            engine_kwargs={"gc_threshold": 1 << 60})
c.elect()
c.put(b"warmup", b"x" * 64)          # settle the pipeline first

print("== 1. one traced put ==")
t = c.enable_tracing()
idx = c.put(b"hello", b"world" * 40)
for _ in range(100):                 # let the followers' applies drain
    if all(nd.last_applied >= idx for nd in c.nodes if nd is not None):
        break
    c.tick()
c.disable_tracing()
(root,) = t.roots("put")
print(trace.render_waterfall(t, root.sid))

print("\n== 2. causality audit ==")
violations = trace.audit(t.events)
print(f"   {len(violations)} violations" +
      ("" if not violations else ": " + "; ".join(violations)))

print("\n== 3. the put's byte bill, by layer ==")
for (op, cat), nbytes in sorted(t.io_sums(root.sid).items()):
    n = sum(1 for s in t.subtree(root.sid)
            if s.name == f"io.{op}" and s.tags.get("category") == cat)
    print(f"   {op:<6} {cat:<10} {n:>3} ops  {nbytes:>6} bytes")
ld = c.leader()
vlog = [s for s in t.subtree(root.sid) if s.name == "io.fsync"
        and s.node == ld.nid and s.tags["category"] == "valuelog"]
print(f"   leader critical-path value-log fsyncs: {len(vlog)} "
      "(the Raft log IS the ValueLog)")

print("\n== 4. scrape (first lines) ==")
for line in c.prometheus_text().splitlines():
    if "fsyncs_total" in line or "repro_node_up" in line:
        print("   " + line)

c.destroy()
print("OK")
