"""Quickstart — the whole system in one minute (CPU):

  1. train a reduced llama-family model with the Nezha checkpoint store,
  2. crash it, restore from the last committed manifest, finish training,
  3. serve it with the paged-KV engine and run a cache GC.

  PYTHONPATH=src python examples/quickstart.py
"""
import shutil
import tempfile

import jax

from repro.configs import ShapeConfig, get
from repro.launch.mesh import make_host_mesh
from repro.runtime.coordinator import TrainRunner
from repro.serve.engine import ServingEngine

cfg = get("smollm_135m", smoke=True)
shape = ShapeConfig("qs", seq_len=32, global_batch=4, kind="train")
mesh = make_host_mesh()
wd = tempfile.mkdtemp(prefix="quickstart_")

print("== 1. train (with Nezha KV-separated checkpoints) ==")
runner = TrainRunner(cfg, shape, mesh, wd, seed=0, ckpt_every=5)
runner.init_or_restore()
try:
    runner.run(20, crash_at=13)
except RuntimeError as e:
    print(f"   injected failure: {e}")

print("== 2. restore from the last committed manifest ==")
runner2 = TrainRunner(cfg, shape, mesh, wd, seed=0, ckpt_every=5)
start = runner2.init_or_restore()
print(f"   resumed at step {start}")
losses = runner2.run(20)
print(f"   final loss {losses[-1]:.4f}")

print("== 3. serve with the paged KV cache + Nezha cache GC ==")
params = runner2.state["params"]
host_params = jax.tree.map(lambda a: a, params)
eng = ServingEngine(cfg.replace(kv_block_size=8), host_params,
                    max_slots=2, max_seq=64)
for p in ([3, 1, 4], [1, 5, 9, 2], [6, 5, 3]):
    eng.submit(p, max_new=6)
eng.run_until_drained()
print(f"   served {len(eng.finished)} requests; "
      f"fragmentation={eng.fragmentation():.2f}")
eng.compact(backend="reference")
print(f"   after cache GC: fragmentation={eng.fragmentation():.2f}")
for r in eng.finished:
    print(f"   req{r.rid}: {r.prompt} -> {r.out}")
shutil.rmtree(wd, ignore_errors=True)
print("OK")
