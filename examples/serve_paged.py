"""Paged-KV serving walk-through: continuous batching over a fragmented
block pool, then a Nezha-style cache GC (the kv_compaction kernel) restoring
contiguous layout — outputs are bit-identical before/after.

  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np

from repro.configs import get
from repro.models import init_params
from repro.serve.engine import ServingEngine

cfg = get("smollm_135m", smoke=True).replace(param_dtype="float32",
                                             kv_block_size=8)
params = init_params(jax.random.PRNGKey(0), cfg)
eng = ServingEngine(cfg, params, max_slots=3, max_seq=64,
                    scramble_blocks=True)

rng = np.random.default_rng(0)
print("== submitting 7 requests into 3 slots (continuous batching) ==")
for i in range(7):
    prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(2, 6))).tolist()
    eng.submit(prompt, max_new=6)
tok = eng.run_until_drained()
print(f"   {tok} tokens across {eng.decode_steps} lockstep decode steps")
print(f"   block-table fragmentation: {eng.fragmentation():.2f} "
      f"(scattered ValueLog state)")

print("== Nezha cache GC (kv_compaction Pallas kernel, interpret mode) ==")
eng.compact(backend="pallas_interpret")
print(f"   fragmentation after GC: {eng.fragmentation():.2f} "
      f"(sorted ValueLog state)")

r = eng.submit([5, 4, 3, 2], max_new=6)
eng.run_until_drained()
print(f"   post-GC decode still correct: req{r.rid} -> {r.out}")
print("OK")
