"""Self-healing drill: train with checkpoints whose manifests commit
through the Nezha cluster, kill -9 a voter mid-run, replace it live
(learner join -> run-shipping catch-up -> auto-promote -> retire the
dead id), and restore the checkpoint from the HEALED cluster — the
manifest survives the membership change because it was committed under
quorum, not stored on the dead node.

  PYTHONPATH=src python examples/self_healing.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.ckpt.nezha_store import NezhaCheckpointStore
from repro.configs import ShapeConfig, get
from repro.core.cluster import Cluster
from repro.data.pipeline import TokenPipeline
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh

cfg = get("smollm_135m", smoke=True)
shape = ShapeConfig("heal", seq_len=32, global_batch=4, kind="train")
wd = tempfile.mkdtemp(prefix="self_heal_")

print("== 3-voter cluster carries the checkpoint manifests ==")
cluster = Cluster(n=3, engine="nezha", workdir=f"{wd}/kv", seed=42,
                  engine_kwargs={"gc_threshold": 256 << 10})
cluster.elect()
store = NezhaCheckpointStore(f"{wd}/ck", cluster=cluster)

print("== phase 1: train 5 steps, checkpoint at step 5 ==")
mesh = make_host_mesh(model=1)
step_fn, rules, st_sh, b_sh = S.make_train_step(cfg, mesh, shape)
init_fn, _ = S.make_init_fn(cfg, mesh)
state = init_fn(jax.random.PRNGKey(0))
pipe = TokenPipeline(cfg, shape, seed=0)
for step in range(5):
    batch = {k: jax.device_put(v, b_sh[k])
             for k, v in pipe.batch_for_step(step).items()}
    state, metrics = step_fn(state, batch)
print(f"   step 5 loss {float(metrics['loss']):.4f}")
saved = jax.tree.map(np.asarray, state)
store.save(5, saved)
print("   manifest committed through the cluster at step 5")

print("== a voter dies hard; the cluster heals itself ==")
victim = [i for i in range(3) if i != cluster.elect().nid][0]
cluster.crash(victim)
new = cluster.replace_node(victim)
ld = cluster.leader()
print(f"   killed node {victim}, joined learner {new}, promoted to "
      f"voter; quorum restored: voters={sorted(ld.voters)}, "
      f"removed={sorted(cluster.removed)}")

print("== restore from the healed cluster ==")
assert store.latest_step() == 5       # manifest scan on the new voter set
host_tree, start = store.restore(S.abstract_state(cfg))
same = all(np.array_equal(a, b) for a, b in
           zip(jax.tree.leaves(host_tree), jax.tree.leaves(saved)))
print(f"   restored step {start}; tensors byte-identical: {same}")
assert same

print("== resume training on the restored state ==")
state_b = jax.tree.map(lambda a, sh: jax.device_put(a, sh), host_tree,
                       st_sh)
for step in range(start, start + 3):
    batch = {k: jax.device_put(v, b_sh[k])
             for k, v in pipe.batch_for_step(step).items()}
    state_b, metrics = step_fn(state_b, batch)
print(f"   resumed {start}->{start + 3}, loss {float(metrics['loss']):.4f}")
pipe.close()
store.close()
cluster.destroy()
shutil.rmtree(wd, ignore_errors=True)
print("OK")
