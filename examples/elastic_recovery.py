"""Elastic rescale drill: train on one mesh, checkpoint via the Nezha store,
then restore the SAME manifest into a different mesh/sharding layout and
continue — the manifest is mesh-agnostic (named tensors + offsets), so
rescaling is a restore, not a conversion.

  PYTHONPATH=src python examples/elastic_recovery.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.ckpt.nezha_store import NezhaCheckpointStore
from repro.configs import ShapeConfig, get
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh

cfg = get("smollm_135m", smoke=True)
shape = ShapeConfig("el", seq_len=32, global_batch=4, kind="train")
wd = tempfile.mkdtemp(prefix="elastic_")

print("== phase 1: mesh A (data=1, model=1) ==")
mesh_a = make_host_mesh(model=1)
step_a, rules, st_sh_a, b_sh_a = S.make_train_step(cfg, mesh_a, shape)
init_a, _ = S.make_init_fn(cfg, mesh_a)
state = init_a(jax.random.PRNGKey(0))
from repro.data.pipeline import TokenPipeline
pipe = TokenPipeline(cfg, shape, seed=0)
for step in range(5):
    batch = {k: jax.device_put(v, b_sh_a[k])
             for k, v in pipe.batch_for_step(step).items()}
    state, metrics = step_a(state, batch)
print(f"   step 5 loss {float(metrics['loss']):.4f}")
store = NezhaCheckpointStore(f"{wd}/ck")
store.save(5, jax.tree.map(np.asarray, state))
print("   manifest committed at step 5")

print("== phase 2: 'rescaled' mesh B — restore the same manifest ==")
mesh_b = make_host_mesh(model=1)   # same devices here; layout path is real
step_b, rules_b, st_sh_b, b_sh_b = S.make_train_step(cfg, mesh_b, shape)
host_tree, start = store.restore(S.abstract_state(cfg))
state_b = jax.tree.map(lambda a, sh: jax.device_put(a, sh), host_tree,
                       st_sh_b)
for step in range(start, start + 5):
    batch = {k: jax.device_put(v, b_sh_b[k])
             for k, v in pipe.batch_for_step(step).items()}
    state_b, metrics = step_b(state_b, batch)
print(f"   resumed {start}->{start + 5}, loss {float(metrics['loss']):.4f}")
pipe.close()
store.close()
shutil.rmtree(wd, ignore_errors=True)
print("OK")
