"""End-to-end driver for the paper's own system: a 3-node KVS-Raft cluster
serving put/get/scan through leader consensus, with GC cycles, a node crash,
recovery, and snapshot catch-up — the full §III lifecycle on real files.

  PYTHONPATH=src python examples/nezha_store_demo.py
"""
import tempfile

from repro.core.cluster import Cluster

wd = tempfile.mkdtemp(prefix="nezha_demo_")
c = Cluster(n=3, engine="nezha", workdir=wd, seed=42,
            engine_kwargs={"gc_threshold": 256 << 10, "gc_batch": 128})
print("== electing a leader ==")
ld = c.elect()
print(f"   node {ld.nid} leads term {ld.current_term}")

print("== loading 600 x 1KiB values (KVS-Raft: one write per value) ==")
items = [(f"user{i:06d}".encode(), bytes([i % 256]) * 1024)
         for i in range(600)]
c.put_many(items)
eng = c.engines[c.elect().nid]
m = c.metrics[c.elect().nid]
print(f"   leader GC cycles: {eng.gc_count}; "
      f"value bytes written 1x to valuelog: "
      f"{m.write_bytes['valuelog'] / 2**20:.1f} MiB "
      f"(user data {eng.user_bytes / 2**20:.1f} MiB)")

print("== three-phase reads (point + range) ==")
print(f"   get(user000150) -> {c.get(b'user000150')[:4]}...")
rows = c.scan(b"user000100", b"user000119")
print(f"   scan 20 keys -> {len(rows)} rows, sorted file hit: "
      f"{m.read_ops.get('sorted_range', 0)} sequential reads")

print("== crash a follower, keep writing, restart, catch up ==")
fol = [i for i in range(3) if i != c.elect().nid][0]
c.crash(fol)
c.put_many([(f"late{i:04d}".encode(), b"z" * 512) for i in range(60)])
dt = c.restart(fol)
c.tick(500)
ok = c.engines[fol].get(b"late0059") == b"z" * 512
print(f"   follower {fol} recovered in {dt * 1e3:.1f} ms; caught up: {ok}")

print("== crash the LEADER; cluster stays available ==")
old = c.elect().nid
c.crash(old)
c.put(b"after_failover", b"still-consistent")
print(f"   new leader {c.elect().nid} serves: "
      f"{c.get(b'after_failover').decode()}")
c.destroy()
print("OK")
