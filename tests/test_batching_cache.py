"""Unit tests for the group-commit batching + read-path caching pipeline:
ValueLog.append_batch, MiniLSM.put_batch / WAL group commit / atomic WAL
truncate, SSTable bloom filters + block cache, SortedStore streaming."""
import os
import tempfile

import pytest

from repro.core.cache import BlockCache, BloomFilter
from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM, SSTable
from repro.core.storage import SortedStore
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog


def _entries(n, vsize=64):
    return [LogEntry(1, i + 1, KIND_PUT, f"k{i:05d}".encode(),
                     bytes([i % 256]) * vsize) for i in range(n)]


# ------------------------------------------------------------- ValueLog
def test_append_batch_equals_sequential_appends():
    wd = tempfile.mkdtemp()
    va = ValueLog(os.path.join(wd, "a.log"), Metrics())
    vb = ValueLog(os.path.join(wd, "b.log"), Metrics())
    es = _entries(40)
    offs_a = [va.append(e) for e in es]
    offs_b = vb.append_batch(es)
    assert offs_a == offs_b
    assert va.size == vb.size
    assert [e for _, e in va.scan()] == [e for _, e in vb.scan()]
    va.delete()
    vb.delete()


def test_group_commit_one_fsync_per_window():
    wd = tempfile.mkdtemp()
    m_per, m_grp = Metrics(), Metrics()
    per = ValueLog(os.path.join(wd, "p.log"), m_per, sync=True)
    grp = ValueLog(os.path.join(wd, "g.log"), m_grp, sync=True,
                   group_commit=True)
    es = _entries(50)
    for e in es:
        per.append(e)                 # fsync per record
    grp.append_batch(es)
    grp.sync_now()                    # ONE fsync for the window
    assert m_per.fsyncs == 50
    assert m_grp.fsyncs == 1
    # identical byte accounting: only the fsync count changes
    assert m_per.write_bytes["valuelog"] == m_grp.write_bytes["valuelog"]
    per.delete()
    grp.delete()


def test_valuelog_read_cache_cuts_bytes():
    wd = tempfile.mkdtemp()
    m = Metrics()
    vl = ValueLog(os.path.join(wd, "c.log"), m, cache=BlockCache(1 << 20))
    offs = vl.append_batch(_entries(20, vsize=128))
    vl.sync_now()
    assert vl.read_at(offs[7]).key == b"k00007"
    cold = m.read_bytes["valuelog"]
    for _ in range(10):
        assert vl.read_at(offs[7]).key == b"k00007"
    assert m.read_bytes["valuelog"] == cold      # all hits, zero new bytes
    assert m.cache_hits["valuelog"] == 10
    # truncation invalidates cached offsets
    vl.truncate_to(offs[5])
    assert len(list(vl.scan())) == 5
    vl.delete()


# -------------------------------------------------------------- MiniLSM
def test_put_batch_equals_puts_and_one_wal_fsync():
    wd = tempfile.mkdtemp()
    m1, m2 = Metrics(), Metrics()
    a = MiniLSM(os.path.join(wd, "a"), m1, wal=True, sync=True)
    b = MiniLSM(os.path.join(wd, "b"), m2, wal=True, sync=True,
                group_commit=True)
    items = [(f"k{i:04d}".encode(), bytes([i % 256]) * 32) for i in range(30)]
    for k, v in items:
        a.put(k, v)
    b.put_batch(items)
    b.sync_wal()
    assert m1.fsyncs == 30 and m2.fsyncs == 1
    assert m1.write_bytes["wal"] == m2.write_bytes["wal"]
    for k, v in items:
        assert a.get(k) == v and b.get(k) == v
    a.destroy()
    b.destroy()


def test_wal_atomic_truncate_and_empty_wal_recovery():
    wd = tempfile.mkdtemp()
    db = MiniLSM(wd, Metrics(), wal=True, memtable_limit=1 << 10)
    for i in range(64):   # crosses the memtable limit -> flush -> truncate
        db.put(f"k{i:03d}".encode(), b"v" * 64)
    db.flush()
    assert os.path.getsize(db._wal_path) == 0   # truncated in place
    db.close()
    db2 = MiniLSM(wd, Metrics(), wal=True)
    assert db2.recover() == 0                   # empty-but-present WAL is fine
    assert db2.get(b"k042") == b"v" * 64
    # new flushes must never reuse a live SSTable filename (would clobber
    # recovered data): after another flush everything stays readable
    live = {s.path for s in db2.l0 + db2.l1}
    db2.put(b"zzz", b"1")
    db2.flush()
    new_paths = {s.path for s in db2.l0 + db2.l1} - live
    assert new_paths and all(p not in live for p in new_paths)
    assert db2.get(b"k042") == b"v" * 64
    assert db2.get(b"zzz") == b"1"
    db2.destroy()


# -------------------------------------------------------------- SSTable
def test_bloom_filter_skips_absent_keys_with_zero_bytes():
    wd = tempfile.mkdtemp()
    m = Metrics()
    items = [(f"k{i:04d}".encode(), bytes([i % 256]) * 100)
             for i in range(0, 400, 2)]     # even keys only
    sst = SSTable.write(os.path.join(wd, "x.sst"), items, m, "flush")
    m.read_bytes.clear()
    misses = [f"k{i:04d}".encode() for i in range(1, 400, 2)]
    skipped = sum(1 for k in misses if sst.get(k) is None)
    assert skipped == len(misses)
    assert m.bloom_skips >= 0.95 * len(misses)  # <=5% false positives
    # bloom negatives cost ZERO read bytes; only fp probes read one block
    assert m.read_bytes.get("sst_point", 0) <= \
        (len(misses) - m.bloom_skips) * (8 << 10)
    for k, v in items[:10]:
        assert sst.get(k) == v
    sst.delete()


def test_block_cache_shared_across_sstables():
    wd = tempfile.mkdtemp()
    m = Metrics()
    cache = BlockCache(1 << 20)
    items = [(f"k{i:04d}".encode(), bytes([i % 256]) * 64)
             for i in range(200)]
    sst = SSTable.write(os.path.join(wd, "y.sst"), items, m, "flush", cache)
    assert sst.get(b"k0100") == bytes([100]) * 64
    cold = m.read_bytes["sst_point"]
    for _ in range(20):
        sst.get(b"k0100")
    assert m.read_bytes["sst_point"] == cold     # served from cache
    assert m.cache_hits["sst_point"] == 20
    sst.delete()
    assert cache.get(sst._cache_ns, 0) is None   # delete invalidates


def test_sstable_load_matches_write():
    wd = tempfile.mkdtemp()
    m = Metrics()
    items = [(f"k{i:04d}".encode(), os.urandom(50)) for i in range(300)]
    path = os.path.join(wd, "z.sst")
    w = SSTable.write(path, items, m, "flush")
    r = SSTable.load(path, m)
    assert r.size == w.size
    assert r.block_keys == w.block_keys
    assert list(r.items()) == items
    for k, v in items[::17]:
        assert r.get(k) == v
    r.delete()


def test_lru_eviction_respects_byte_budget():
    c = BlockCache(1000, max_entry_bytes=400)
    c.put(1, 0, b"a" * 400)
    c.put(1, 1, b"b" * 400)
    c.put(1, 2, b"c" * 400)     # evicts block 0
    assert c.get(1, 0) is None
    assert c.get(1, 1) == b"b" * 400
    assert c.size_bytes <= 1000
    c.put(1, 3, b"too big" * 100)   # > max_entry: not cached
    assert c.get(1, 3) is None


def test_bloom_false_positive_rate_reasonable():
    bf = BloomFilter(1000)
    for i in range(1000):
        bf.add(f"key{i}".encode())
    assert all(f"key{i}".encode() in bf for i in range(1000))
    fp = sum(1 for i in range(1000) if f"other{i}".encode() in bf)
    assert fp < 50


# ----------------------------------------------------------- SortedStore
def test_sorted_store_streaming_load_accounts_identical_bytes():
    wd = tempfile.mkdtemp()
    m = Metrics()
    s = SortedStore(wd, m, gen=1)
    items = [(f"k{i:03d}".encode(),
              LogEntry(1, i + 1, KIND_PUT, f"k{i:03d}".encode(), b"x" * 500))
             for i in range(100)]
    s.build(iter(items), last_index=100, last_term=1)
    fsize = os.path.getsize(s.path)
    m2 = Metrics()
    s2 = SortedStore(wd, m2, gen=1)
    s2.load()
    assert m2.read_bytes["recover_sorted"] == fsize   # identical byte total
    assert s2.last_key_on_disk() == b"k099"
    assert m2.read_bytes["gc_resume_scan"] == fsize
    assert s2.get(b"k050") == b"x" * 500
    s2.destroy()


def test_sorted_store_streaming_handles_chunk_boundaries():
    wd = tempfile.mkdtemp()
    s = SortedStore(wd, Metrics(), gen=2)
    s.CHUNK_BYTES = 256          # force records to straddle chunk edges
    items = [(f"k{i:03d}".encode(),
              LogEntry(1, i + 1, KIND_PUT, f"k{i:03d}".encode(),
                       os.urandom(90 + i % 37)))
             for i in range(80)]
    s.build(iter(items), last_index=80, last_term=1)
    s2 = SortedStore(wd, Metrics(), gen=2)
    s2.CHUNK_BYTES = 256
    assert s2.load()
    assert s2.keys == [k for k, _ in items]
    got = dict((k, e.value) for k, e in s2.items())
    assert got == {k: e.value for k, e in items}
    s2.destroy()


def test_sorted_store_point_cache():
    wd = tempfile.mkdtemp()
    m = Metrics()
    s = SortedStore(wd, m, gen=3, cache=BlockCache(1 << 20))
    items = [(f"k{i:03d}".encode(),
              LogEntry(1, i + 1, KIND_PUT, f"k{i:03d}".encode(), b"y" * 200))
             for i in range(50)]
    s.build(iter(items), last_index=50, last_term=1)
    assert s.get(b"k025") == b"y" * 200
    cold = m.read_bytes["sorted_point"]
    for _ in range(5):
        s.get(b"k025")
    assert m.read_bytes["sorted_point"] == cold
    assert m.cache_hits["sorted_point"] == 5
    s.destroy()
