"""End-to-end operation tracing (repro.core.trace).

Load-bearing claims under test:

  * Determinism — the serialized span tree of a traced chaos run is a
    pure function of {seed, schedule}: byte-identical to_json() across
    repeats, and installing the tracer perturbs NOTHING (the SimNet
    delivery order and every Metrics counter match an untraced
    same-seed run exactly).
  * Cross-node propagation — one put's root span contains the leader's
    raft.append, every follower's follower.append (durable fsync
    included), and the apply spans on all three nodes; the tree stays
    connected across a leadership change, a node restart, and the
    InstallSnapshot fallback (learner catch-up).  Spans whose parent
    crossed a tracer swap are flagged ``orphan`` at export — kept,
    never silently dropped.
  * Causality auditor — zero violations on healthy and chaos runs;
    hand-built event streams with ack-before-durable,
    commit-before-quorum, apply-before-commit and
    client-ack-before-apply are each flagged.
  * Reconciliation — io-span byte sums equal the Metrics counter deltas
    for the same run, per (op, category).
  * MetricsRegistry — label validation, deterministic Prometheus text,
    JSON scrape; Cluster.registry() publishes per-node families.
  * SimNet drop attribution — dropped_msgs == sum(drop_reasons), with
    the partition/lossy/down/removed/crash_flush causes split out.
"""
import json
import tempfile

import pytest

from repro.core import trace
from repro.core.cluster import Cluster
from repro.core.metrics import Metrics
from repro.core.raft import LEADER
from repro.core.simnet import SimNet
from repro.core.trace import MetricsRegistry, Tracer, audit, render_waterfall
from repro.core.workload import (ChaosSchedule, Tenant, WorkloadSpec,
                                 run_workload)

pytestmark = pytest.mark.trace


@pytest.fixture(autouse=True)
def _no_global_tracer_leaks():
    """The tracer is process-global (faultfs pattern): never let one
    test's tracer observe another test's cluster."""
    trace.uninstall()
    yield
    trace.uninstall()


def _mk(seed=4, sync=False, **engine_kw):
    wd = tempfile.mkdtemp(prefix="trace_")
    kw = {"gc_threshold": 1 << 60}
    kw.update(engine_kw)
    return Cluster(n=3, engine="nezha", workdir=wd, seed=seed,
                   sync=sync, engine_kwargs=kw)


def _close(c):
    for e in c.engines:
        if e is not None:
            e.close()


# ------------------------------------------------------------ determinism
def _traced_chaos_json(chaos_seed=11, cluster_seed=4, n_ops=120):
    c = _mk(seed=cluster_seed)
    t = c.enable_tracing()
    spec = WorkloadSpec(rate=5000.0, n_ops=n_ops, n_keys=60, vsize=64,
                        seed=3, tenants=(Tenant("t", 1.0, "A"),))
    rep = run_workload(c, spec, ChaosSchedule.generate(chaos_seed,
                                                       n_cycles=2))
    c.disable_tracing()
    out = t.to_json()
    _close(c)
    return out, rep


def test_same_seed_byte_identical_trace():
    j1, rep1 = _traced_chaos_json()
    j2, rep2 = _traced_chaos_json()
    assert j1 == j2, "span tree diverged across same-{seed, schedule} runs"
    assert rep1.violations == []
    doc = json.loads(j1)
    assert doc["spans"] and doc["events"] and doc["net_events"]
    # chaos faults are annotated into the event stream, time-aligned
    assert any(e["kind"] == "fault" for e in doc["events"])


def test_tracer_does_not_perturb_the_simulation():
    """Same seed, tracer on vs off: identical SimNet delivery order and
    identical byte accounting — tracing is pure observation."""
    runs = []
    for traced in (False, True):
        c = _mk(seed=6)
        c.net.enable_trace()
        if traced:
            c.enable_tracing()
        c.elect()
        for i in range(25):
            c.put(b"k%04d" % i, b"v" * 64)
        assert c.get(b"k0007") == b"v" * 64
        runs.append((list(c.net.trace), c.net.time, c.net.sent_msgs,
                     [dict(m.write_bytes) for m in c.metrics],
                     [m.fsyncs for m in c.metrics]))
        c.disable_tracing()
        _close(c)
    assert runs[0] == runs[1]


# ------------------------------------------------------- span propagation
def test_put_root_span_connects_all_three_nodes():
    c = _mk(sync=True)
    t = c.enable_tracing()
    c.elect()
    idx = c.put(b"alpha", b"beta" * 16)
    for _ in range(100):        # drain the followers' apply pipelines
        if all(nd.last_applied >= idx for nd in c.nodes if nd is not None):
            break
        c.tick()
    (root,) = t.roots("put")
    assert root.tags["index"] == idx
    sub = t.subtree(root.sid)
    ld = c.leader()
    touched = {s.node for s in sub if s.kind == "raft"}
    assert touched == {0, 1, 2}, "follower appends not grafted onto root"
    applies = {s.node for s in sub if s.name == "apply"}
    assert applies == {0, 1, 2}
    # the leader's durable point: exactly one value-log fsync on the
    # put's critical path (the Raft-log-IS-the-ValueLog design)
    leader_vlog_fsyncs = [s for s in sub if s.name == "io.fsync"
                          and s.node == ld.nid
                          and s.tags["category"] == "valuelog"]
    assert len(leader_vlog_fsyncs) == 1
    assert audit(t.events) == []
    # the waterfall renders the same tree for humans
    art = render_waterfall(t, root.sid)
    assert "put" in art and "follower.append" in art
    _close(c)


def test_propagation_across_leadership_change():
    c = _mk(sync=True)
    t = c.enable_tracing()
    ld = c.elect()
    for i in range(8):
        c.put(b"k%04d" % i, b"v" * 32)
    c.crash(ld.nid)
    new = c.elect()
    assert new.nid != ld.nid
    for i in range(8, 16):
        c.put(b"k%04d" % i, b"v" * 32)
    assert c.get(b"k0012") == b"v" * 32
    assert audit(t.events) == [], "failover broke a causality invariant"
    roots = t.roots("put")
    assert len(roots) == 16
    # post-failover puts graft onto the NEW leader and stay connected
    late = t.subtree(roots[-1].sid)
    assert any(s.name == "follower.append" for s in late)
    assert not any(d.get("orphan") for d in t.export()["spans"])
    _close(c)


def test_propagation_across_node_restart():
    c = _mk(sync=True)
    t = c.enable_tracing()
    ld = c.elect()
    victim = (ld.nid + 1) % 3
    for i in range(6):
        c.put(b"k%04d" % i, b"v" * 32)
    c.crash(victim)
    for i in range(6, 12):
        c.put(b"k%04d" % i, b"v" * 32)
    c.restart(victim)
    for _ in range(400):
        nd = c.nodes[victim]
        if nd is not None and nd.last_applied >= c.leader().commit_index:
            break
        c.tick()
    # the restarted node re-acked its recovered log: the baseline events
    # emitted at recovery keep that from reading as ack-before-durable
    assert any(e["kind"] == "durable" and e.get("baseline")
               and e["node"] == victim for e in t.events)
    assert audit(t.events) == []
    assert not any(d.get("orphan") for d in t.export()["spans"])
    _close(c)


def test_propagation_across_install_snapshot_fallback():
    """Learner catch-up goes through InstallSnapshot: the install span
    lands on the new node, the snapshot counts as durable+applied for
    the auditor, and the tree stays connected."""
    c = _mk(sync=True, gc_threshold=4096)
    t = c.enable_tracing()
    c.elect()
    for i in range(30):
        c.put(b"k%04d" % i, b"v%04d" % i)
    c.force_gc()
    new = c.add_node()
    assert c.wait_promoted(new)
    installs = [s for s in t.spans if s.name == "install_snapshot"]
    assert installs and any(s.node == new for s in installs)
    assert any(e["kind"] == "snapshot_install" and e["node"] == new
               for e in t.events)
    assert audit(t.events) == []
    assert not any(d.get("orphan") for d in t.export()["spans"])
    _close(c)


def test_orphan_spans_flagged_not_dropped():
    t = Tracer()
    sid = t.begin("stray", parent=9999)
    t.end(sid)
    (d,) = t.export()["spans"]
    assert d["orphan"] is True and d["name"] == "stray"
    # a span whose parent EXISTS is not flagged
    t2 = Tracer()
    root = t2.begin("root")
    kid = t2.begin("kid")
    t2.end(kid)
    t2.end(root)
    assert not any(s.get("orphan") for s in t2.export()["spans"])


def test_mid_run_tracer_install_emits_baselines():
    """Installing the tracer on a cluster with history must seed
    durable/commit/apply baselines, or the first post-install ack reads
    as a violation."""
    c = _mk(sync=True)
    c.elect()
    for i in range(10):
        c.put(b"k%04d" % i, b"v" * 32)
    t = c.enable_tracing()           # mid-run: state predates the tracer
    for i in range(10, 20):
        c.put(b"k%04d" % i, b"v" * 32)
    assert audit(t.events) == []
    kinds = {e["kind"] for e in t.events if e.get("baseline")}
    assert {"durable", "commit_learned", "apply"} <= kinds
    _close(c)


# -------------------------------------------------------------- auditor
def test_audit_flags_each_violation_class():
    base = {"t": 0}

    def ev(kind, node, index, **kw):
        return dict(base, kind=kind, node=node, index=index, **kw)

    # ack before durable
    v = audit([ev("ack_sent", 1, 5, to=0)])
    assert len(v) == 1 and "before durable" in v[0]
    # commit without quorum: only the leader's own durability
    v = audit([ev("durable", 0, 5),
               ev("commit", 0, 5, voters=[0, 1, 2])])
    assert len(v) == 1 and "before quorum" in v[0]
    # apply before commit
    v = audit([ev("durable", 2, 5), ev("apply", 2, 5)])
    assert len(v) == 1 and "before commit" in v[0]
    # client ack before apply
    v = audit([ev("client_ack", 0, 5)])
    assert len(v) == 1 and "before apply" in v[0]


def test_audit_accepts_clean_protocol_round():
    evs = [
        {"t": 0, "kind": "durable", "node": 0, "index": 1},
        {"t": 1, "kind": "durable", "node": 1, "index": 1},
        {"t": 1, "kind": "ack_sent", "node": 1, "index": 1, "to": 0},
        {"t": 2, "kind": "ack_recv", "node": 0, "index": 1, "from": 1},
        {"t": 2, "kind": "commit", "node": 0, "index": 1,
         "voters": [0, 1, 2]},
        {"t": 3, "kind": "apply", "node": 0, "index": 1},
        {"t": 3, "kind": "client_ack", "node": 0, "index": 1},
        {"t": 4, "kind": "fault", "node": -1, "index": 0},  # annotation
    ]
    assert audit(evs) == []
    # snapshot_install stands in for durable+commit+apply
    assert audit([
        {"t": 0, "kind": "snapshot_install", "node": 3, "index": 9},
        {"t": 1, "kind": "ack_sent", "node": 3, "index": 9, "to": 0},
        {"t": 2, "kind": "apply", "node": 3, "index": 9},
    ]) == []


# -------------------------------------------------------- reconciliation
def test_io_span_sums_reconcile_with_metrics_counters():
    """Every byte the Metrics counters saw during the traced window is
    an io span, and vice versa — exact, not approximate."""
    c = _mk(sync=True)
    c.elect()
    before = [m.snapshot() for m in c.metrics]
    t = c.enable_tracing()
    for i in range(20):
        c.put(b"r%04d" % i, b"x" * 96)
    assert c.get(b"r0011") == b"x" * 96
    c.disable_tracing()
    sums = t.io_sums()
    for op, attr in (("write", "write_bytes"), ("read", "read_bytes")):
        want = {}
        for m, b4 in zip(c.metrics, before):
            for cat, n in m.delta(b4)[attr].items():
                want[cat] = want.get(cat, 0) + n
        got = {cat: n for (o, cat), n in sums.items() if o == op}
        got = {k: v for k, v in got.items() if v}
        want = {k: v for k, v in want.items() if v}
        assert got == want, f"{op} bytes diverged from Metrics"
    fsyncs = sum(1 for s in t.spans if s.name == "io.fsync")
    want_fsyncs = sum(m.delta(b4)["fsyncs"]
                      for m, b4 in zip(c.metrics, before))
    assert fsyncs == want_fsyncs
    _close(c)


# ------------------------------------------------------ metrics registry
def test_registry_families_and_exposition():
    reg = MetricsRegistry()
    ops = reg.counter("repro_ops_total", "ops by kind", ["kind"])
    ops.labels(kind="put").inc(3)
    ops.labels(kind="get").inc()
    reg.gauge("repro_up", "liveness").set(1)
    h = reg.histogram("repro_lat_us", "latency", ["op"])
    for v in (10, 20, 30):
        h.labels(op="put").observe(v)
    text = reg.prometheus_text()
    assert '# TYPE repro_ops_total counter' in text
    assert 'repro_ops_total{kind="put"} 3' in text
    assert 'repro_lat_us_count{op="put"} 3' in text
    assert '# TYPE repro_lat_us summary' in text
    assert text == reg.prometheus_text()        # deterministic
    doc = reg.scrape()
    assert doc["repro_ops_total"]["samples"][0]["labels"] == {"kind": "get"}
    json.dumps(doc)                             # scrape is JSON-able
    with pytest.raises(ValueError, match="takes labels"):
        ops.labels(wrong="x")
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("repro_ops_total", "", ["kind"])


def test_cluster_registry_and_health_report_publish_metrics():
    c = _mk(sync=True)
    c.elect()
    for i in range(5):
        c.put(b"m%04d" % i, b"v" * 64)
    text = c.prometheus_text()
    assert 'repro_fsyncs_total{category="valuelog",node="0"}' in text
    assert 'repro_node_up{node="1"} 1' in text
    hr = c.health_report()
    json.dumps(hr)
    assert hr["metrics"]["repro_raft_commit_index"]["samples"]
    assert hr["net"]["drop_reasons"] == {}
    # per-node fsync categories also surface via Metrics.summary()
    assert c.metrics[0].summary()["fsync_cats"].get("valuelog", 0) > 0
    _close(c)


# ------------------------------------------------- simnet drop attribution
def test_drop_reasons_partition_lossy_down_removed():
    net = SimNet([0, 1, 2], seed=1)
    net.partition(0, 1)
    net.send(0, 1, "m")
    net.heal()
    net.crash(2)
    net.send(0, 2, "m")
    net.restart(2)
    net.drop_prob = 1.0
    net.send(0, 1, "m")
    net.drop_prob = 0.0
    net.send(0, 2, "in-flight")      # queued, then destroyed by crash
    net.crash(2)
    net.restart(2)
    net.remove_node(1)
    net.send(0, 1, "m")
    assert dict(net.drop_reasons) == {
        "partition": 1, "down": 1, "lossy": 1, "crash_flush": 1,
        "removed": 1}
    assert net.dropped_msgs == sum(net.drop_reasons.values())


def test_drops_flow_into_tracer_net_events():
    t = trace.install(Tracer())
    try:
        net = SimNet([0, 1], seed=1)
        net.partition(0, 1)
        net.send(0, 1, "x")
        net.heal()
        net.send(0, 1, "y")
        net.time = 100
        net.deliver(1)
        kinds = [(e[0], e[5]) for e in t.net_events]
        assert ("drop", "partition") in kinds
        assert ("send", None) in kinds and ("deliver", None) in kinds
    finally:
        trace.uninstall()
