"""Crash-point fault injection: kill -9 at EVERY numbered I/O op.

Load-bearing claims under test:

  * FaultFS semantics: unsynced buffered bytes vanish (drop), a
    deterministic sector-aligned prefix of the unsynced tail may survive
    (torn), and an os.replace whose parent directory was never fsynced
    can be lost (lost_rename); everything fsynced stays.  SimulatedCrash
    is a BaseException so a stray `except Exception` in a recovery helper
    cannot swallow a kill -9.
  * write_json_atomic is all-or-nothing at every crash index and in every
    mode: the destination is byte-equal to the old OR the new document,
    never empty, torn, or unparsable.
  * the exhaustive sweep: the seeded single-node workload is killed at
    every I/O op index under all three modes, recovered, and must lose no
    acked write (check_history) and keep manifest/run-set/raft-log
    integrity (_audit_cluster).  `CRASHPOINT_N_OPS=48 make crash` widens
    the workload for a longer sweep; the tier-1 default is exhaustive at
    smoke scale.
  * full-cluster restart: ALL n nodes die at the same torn I/O point
    (fleet power loss) and must converge byte-equal with no acked loss.
  * mid-op chaos: kill_leader_mid_put / crash_mid_gc / crash_mid_adoption
    kill nodes INSIDE a put / GC cycle / run adoption, and the workload
    checker still reports zero violations.

Every failure reproduces from {seed, crash_index, mode} alone — the
assertion messages carry the exact run_crashpoint() call to paste.
"""
import os

import pytest

from repro.core.cluster import Cluster
from repro.core.faultfs import (MODES, FaultFS, SimulatedCrash, fs_fsync,
                                fs_open, install, uninstall,
                                write_json_atomic)
from repro.core.workload import (ChaosSchedule, FaultEvent, WorkloadSpec,
                                 run_crashpoint, run_full_restart,
                                 run_workload)

SWEEP_N_OPS = int(os.environ.get("CRASHPOINT_N_OPS", "18"))


@pytest.fixture
def fs():
    f = install(FaultFS(seed=1))
    yield f
    uninstall()


# ------------------------------------------------------- shim semantics
def test_unsynced_bytes_drop(fs, tmp_path):
    p = str(tmp_path / "seg.log")
    f = fs_open(p, "wb")
    f.write(b"A" * 100)
    fs_fsync(f)
    f.write(b"B" * 100)            # never synced: gone at the crash
    fs.materialize(str(tmp_path) + os.sep)
    with open(p, "rb") as r:
        assert r.read() == b"A" * 100
    assert fs.injected["dropped_bytes"] == 100


def test_unsynced_new_file_never_existed(fs, tmp_path):
    p = str(tmp_path / "fresh.log")
    f = fs_open(p, "wb")
    f.write(b"data")
    fs.materialize(str(tmp_path) + os.sep)
    assert not os.path.exists(p)


def test_torn_tail_sector_aligned_and_deterministic(tmp_path):
    def run(sub):
        f = install(FaultFS(seed=33, sector=128))
        try:
            d = tmp_path / sub
            d.mkdir()
            p = str(d / "seg.log")
            h = fs_open(p, "wb")
            h.write(b"S" * 64)
            fs_fsync(h)
            h.write(b"U" * 1000)   # unsynced tail: torn at the crash
            f.materialize(str(d) + os.sep, mode="torn")
            with open(p, "rb") as r:
                return r.read()
        finally:
            uninstall()

    a, b = run("a"), run("b")
    assert a == b                  # pure function of {seed, op index, mode}
    assert a[:64] == b"S" * 64     # synced prefix always survives
    extra = len(a) - 64
    assert extra % 128 == 0 or extra == 1000


def test_lost_rename_undone_without_dirsync(fs, tmp_path):
    dst, tmp = str(tmp_path / "meta.json"), str(tmp_path / "meta.json.tmp")
    h = fs_open(dst, "wb")
    h.write(b"v1")
    fs_fsync(h)
    h.close()
    h = fs_open(tmp, "wb")
    h.write(b"v2")
    fs_fsync(h)
    h.close()
    fs.replace(tmp, dst)           # rename, but the dir entry never synced
    fs.materialize(str(tmp_path) + os.sep, mode="lost_rename")
    with open(dst, "rb") as r:
        assert r.read() == b"v1"   # dst reverted
    with open(tmp, "rb") as r:
        assert r.read() == b"v2"   # src reappeared with its durable bytes
    assert fs.injected["lost_renames"] == 1


def test_dirsync_pins_the_rename(fs, tmp_path):
    dst, tmp = str(tmp_path / "meta.json"), str(tmp_path / "meta.json.tmp")
    h = fs_open(tmp, "wb")
    h.write(b"v2")
    fs_fsync(h)
    h.close()
    fs.replace(tmp, dst)
    fs.dirsync(str(tmp_path))
    fs.materialize(str(tmp_path) + os.sep, mode="lost_rename")
    with open(dst, "rb") as r:
        assert r.read() == b"v2"
    assert not os.path.exists(tmp)


@pytest.mark.parametrize("mode", MODES)
def test_write_json_atomic_is_all_or_nothing(mode, tmp_path):
    """Micro-sweep: crash write_json_atomic at every one of its I/O ops,
    in every mode — the destination must be the OLD doc or the NEW doc,
    never empty or torn (the two bugs the pattern exists to prevent)."""
    import json
    for k in range(8):             # the pattern issues 4 ops; over-cover
        d = tmp_path / f"{mode}{k}"
        d.mkdir()
        p = str(d / "state.json")
        f = install(FaultFS(seed=2))
        try:
            write_json_atomic(p, {"v": "old"})
            f.arm(k, scope=str(d) + os.sep, mode=mode)
            try:
                write_json_atomic(p, {"v": "new"})
            except SimulatedCrash:
                pass
            f.materialize(str(d) + os.sep)
            with open(p) as r:
                assert json.load(r)["v"] in ("old", "new"), (mode, k)
        finally:
            uninstall()


def test_kill9_not_swallowed_by_except_exception(fs, tmp_path):
    fs.arm(0, mode="drop")
    h = fs_open(str(tmp_path / "x.log"), "wb")
    with pytest.raises(SimulatedCrash):
        try:
            h.write(b"data")
        except Exception:          # the stray clause recovery helpers have
            pytest.fail("except Exception swallowed a kill -9")


def test_scope_binds_to_directory_not_prefix(fs, tmp_path):
    """node1/ must not match node10/ (the abspath-strips-trailing-sep
    regression)."""
    for d in ("node1", "node10"):
        (tmp_path / d).mkdir()
    fs.arm(0, scope=str(tmp_path / "node1") + os.sep, mode="drop")
    h = fs_open(str(tmp_path / "node10" / "a.log"), "wb")
    h.write(b"ok")                 # out of scope: no crash
    h.close()
    with pytest.raises(SimulatedCrash):
        fs_open(str(tmp_path / "node1" / "a.log"), "wb").write(b"boom")


def test_abandoned_handle_cannot_flush_later(fs, tmp_path):
    """Wrapped handles are raw: dropping one without close() (kill -9)
    leaves nothing buffered that could land afterwards, and materialize
    takes the fd with it."""
    p = str(tmp_path / "seg.log")
    h = fs_open(p, "wb")
    h.write(b"X" * 10)             # write-through: already on disk
    with open(p, "rb") as r:
        assert r.read() == b"X" * 10
    fs.materialize(str(tmp_path) + os.sep)   # force-closes the handle
    assert h.closed
    assert not os.path.exists(p)   # never synced, never durable


# --------------------------------------------------- crash-point sweeps
def test_record_run_is_deterministic(tmp_path):
    a = run_crashpoint(str(tmp_path / "a"), seed=11, n_ops=SWEEP_N_OPS)
    b = run_crashpoint(str(tmp_path / "b"), seed=11, n_ops=SWEEP_N_OPS)
    assert not a["crashed"] and a["recovered_ok"]
    assert a["ops"] == b["ops"]    # the sweep domain replays exactly


def test_probe_crash_site_is_reproducible(tmp_path):
    a = run_crashpoint(str(tmp_path / "a"), seed=11, crash_index=40,
                       mode="torn", n_ops=SWEEP_N_OPS)
    b = run_crashpoint(str(tmp_path / "b"), seed=11, crash_index=40,
                       mode="torn", n_ops=SWEEP_N_OPS)
    assert a["crash"] == b["crash"]
    assert a["crashed"] and b["crashed"]


@pytest.mark.crashpoint
@pytest.mark.parametrize("mode", MODES)
def test_exhaustive_crashpoint_sweep(mode, tmp_path):
    """Every numbered I/O op of the seeded workload is a crash point:
    kill -9 there, recover, and require zero acked-write loss + a clean
    structural audit."""
    rec = run_crashpoint(str(tmp_path / "record"), seed=11,
                         n_ops=SWEEP_N_OPS)
    assert rec["recovered_ok"] and not rec["crashed"]
    failures = []
    for k in range(rec["ops"]):
        r = run_crashpoint(str(tmp_path / f"p{k}"), seed=11, crash_index=k,
                           mode=mode, n_ops=SWEEP_N_OPS)
        assert r["crashed"], f"crash index {k} never fired"
        if not r["recovered_ok"]:
            failures.append((k, r["crash"], r["violations"][:2],
                             r["audit"][:2]))
    assert not failures, (
        f"{len(failures)}/{rec['ops']} crash points lost acked state under "
        f"{mode!r}: {failures[:5]} — reproduce any with "
        f"run_crashpoint(dir, seed=11, crash_index=K, mode={mode!r}, "
        f"n_ops={SWEEP_N_OPS})")


@pytest.mark.crashpoint
@pytest.mark.parametrize("engine", ["original", "dwisckey", "nezha_nogc"])
def test_crashpoint_sweep_baseline_engines(engine, tmp_path):
    """The baseline engines' persistence (raft vlog / WAL / wisc vlog)
    survives the same sweep — strided, cycling the three modes so every
    index crashes in at least one mode across the engines."""
    rec = run_crashpoint(str(tmp_path / "record"), seed=4, engine=engine,
                         n_ops=SWEEP_N_OPS)
    assert rec["recovered_ok"] and not rec["crashed"]
    for k in range(0, rec["ops"], 3):
        mode = MODES[(k // 3) % len(MODES)]
        r = run_crashpoint(str(tmp_path / f"p{k}"), seed=4, crash_index=k,
                           mode=mode, engine=engine, n_ops=SWEEP_N_OPS)
        assert r["crashed"] and r["recovered_ok"], (
            f"run_crashpoint(dir, seed=4, crash_index={k}, mode={mode!r}, "
            f"engine={engine!r}, n_ops={SWEEP_N_OPS}) -> "
            f"{r['violations'][:3]} {r['audit'][:3]}")


@pytest.mark.crashpoint
@pytest.mark.parametrize("mode", MODES)
def test_full_cluster_restart_durability_gate(mode, tmp_path):
    """Fleet power loss at a (torn) I/O point: every node restarts from
    its durable view, no acked write lost, byte-equal scans everywhere."""
    for k in (25, 80, 200, 450):
        r = run_full_restart(str(tmp_path / f"f{k}"), seed=9,
                             crash_index=k, mode=mode)
        assert r["recovered_ok"], (
            f"run_full_restart(dir, seed=9, crash_index={k}, "
            f"mode={mode!r}) -> converged={r['converged']} "
            f"{r['violations'][:3]} {r['audit'][:3]}")


# ------------------------------------------------------- mid-op chaos
def test_mid_op_chaos_schedule_keeps_history_clean(tmp_path):
    """kill_leader_mid_put + crash_mid_gc + crash_mid_adoption, each with
    a restart: zero checker violations, and the health report counts the
    injected faults."""
    f = install(FaultFS(seed=7))
    try:
        c = Cluster(n=3, engine="nezha", workdir=str(tmp_path / "w"),
                    seed=7, sync=True, engine_kwargs={"gc_threshold": 4096})
        c.elect()
        sched = ChaosSchedule([
            FaultEvent(0.20, "kill_leader_mid_put"),
            FaultEvent(0.40, "restart", recovery=True),
            FaultEvent(0.55, "crash_mid_gc"),
            FaultEvent(0.70, "restart", recovery=True),
            FaultEvent(0.80, "crash_mid_adoption"),
            FaultEvent(0.92, "restart", recovery=True),
        ], seed=7)
        spec = WorkloadSpec(n_ops=120, n_keys=60, vsize=128, seed=7,
                            virtual_time=True)
        rep = run_workload(c, spec, chaos=sched)
        assert rep.violations == []
        faults = c.health_report()["faults"]
        assert sum(pn.get("mid_op_crash", 0)
                   for pn in faults["per_node"]) >= 1
        assert faults["faultfs"]["crashes"] >= 1
    finally:
        uninstall()


def test_mid_op_actions_degrade_without_faultfs(tmp_path):
    """The same schedule with no FaultFS installed degrades to polite
    faults (kill / gc_storm / no-op) — schedules stay portable."""
    c = Cluster(n=3, engine="nezha", workdir=str(tmp_path / "w"), seed=3,
                engine_kwargs={"gc_threshold": 4096})
    c.elect()
    sched = ChaosSchedule([
        FaultEvent(0.30, "kill_leader_mid_put"),
        FaultEvent(0.55, "restart", recovery=True),
        FaultEvent(0.70, "crash_mid_gc", recovery=True),
    ], seed=3)
    rep = run_workload(c, WorkloadSpec(n_ops=80, n_keys=40, seed=3,
                                       virtual_time=True), chaos=sched)
    assert rep.violations == []
    kills = [t for t in rep.timeline if t["action"] == "kill_leader_mid_put"]
    assert kills and kills[0]["detail"] is not None


# -------------------------------------------------- cluster-level bits
def test_cluster_recover_flag_full_restart(tmp_path):
    """Cluster(recover=True) boots every node from an existing workdir
    (the politely-shut-down case; the torn cases live in the sweeps)."""
    wd = str(tmp_path / "c")
    c = Cluster(n=3, engine="nezha", workdir=wd, seed=2, sync=True,
                engine_kwargs={"gc_threshold": 4096})
    c.elect()
    items = {b"k%04d" % i: b"v%04d" % i * 20 for i in range(12)}
    for k, v in items.items():
        c.put(k, v)
    for e in c.engines:
        e.close()
    rec = Cluster(n=3, engine="nezha", workdir=wd, seed=5, recover=True)
    rec.elect()
    rec.put(b"zz-liveness", b"alive")
    for k, v in items.items():
        assert rec.get(k) == v
    rec.destroy()


def test_virtual_time_latencies_are_deterministic(tmp_path):
    """virtual_time=True: identical seeds give IDENTICAL tail quantiles
    (ticks * tick_us), independent of host CPU load."""
    def one(sub):
        c = Cluster(n=3, engine="nezha", workdir=str(tmp_path / sub),
                    seed=6, engine_kwargs={"gc_threshold": 8192})
        c.elect()
        rep = run_workload(
            c, WorkloadSpec(n_ops=100, n_keys=50, seed=6,
                            virtual_time=True),
            chaos=ChaosSchedule.kill_and_recover(seed=6))
        assert rep.violations == []
        return {lab: h.summary() for lab, h in rep.hist.items()}

    assert one("a") == one("b")
