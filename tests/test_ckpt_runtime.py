"""Checkpoint store + fault-tolerant runtime integration tests."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.nezha_store import NezhaCheckpointStore
from repro.configs import get, ShapeConfig
from repro.core.metrics import Metrics
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.runtime.coordinator import Coordinator, TrainRunner

SHAPE = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")


def tiny_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (64, 32)),
            "b": {"w": jax.random.normal(k, (8,)),
                  "s": jnp.zeros((), jnp.int32)}}


def test_ckpt_roundtrip_and_single_write():
    wd = tempfile.mkdtemp()
    m = Metrics()
    store = NezhaCheckpointStore(wd, m, gc_threshold_bytes=1 << 60)
    tree = tiny_tree()
    store.save(10, tree)
    user = sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))
    assert m.write_bytes["ckpt_valuelog"] == user          # exactly once
    restored, step = store.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    store.close()


def test_ckpt_gc_compacts_and_keeps_latest():
    wd = tempfile.mkdtemp()
    store = NezhaCheckpointStore(wd, gc_threshold_bytes=1 << 60, keep=2)
    for s in range(1, 6):
        store.save(s, tiny_tree(seed=s))
    store.gc()
    assert sorted(store.manifests) == [4, 5]
    r4, _ = store.restore(tiny_tree(), step=4)
    exp = tiny_tree(seed=4)
    for a, b in zip(jax.tree.leaves(exp), jax.tree.leaves(r4)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # old vlogs physically removed
    vlogs = [f for f in os.listdir(wd) if f.endswith(".vlog")]
    assert len(vlogs) == 1
    store.close()


def test_ckpt_reload_from_disk():
    wd = tempfile.mkdtemp()
    store = NezhaCheckpointStore(wd)
    store.save(3, tiny_tree(seed=3))
    store.close()
    store2 = NezhaCheckpointStore(wd)
    assert store2.latest_step() == 3
    r, _ = store2.restore(tiny_tree())
    exp = tiny_tree(seed=3)
    assert np.array_equal(np.asarray(r["a"]), np.asarray(exp["a"]))
    store2.close()


@pytest.mark.slow
def test_crash_restore_bit_identical_losses():
    cfg = get("smollm_135m", smoke=True)
    mesh = make_host_mesh()
    wd1, wd2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        r = TrainRunner(cfg, SHAPE, mesh, wd1, seed=7, ckpt_every=4)
        r.init_or_restore()
        ref = r.run(12)

        r2 = TrainRunner(cfg, SHAPE, mesh, wd2, seed=7, ckpt_every=4)
        r2.init_or_restore()
        with pytest.raises(RuntimeError):
            r2.run(12, crash_at=10)
        r3 = TrainRunner(cfg, SHAPE, mesh, wd2, seed=7, ckpt_every=4)
        start = r3.init_or_restore()
        assert start == 8
        resumed = r3.run(12)
        assert ref[start:] == resumed
    finally:
        shutil.rmtree(wd1, ignore_errors=True)
        shutil.rmtree(wd2, ignore_errors=True)


def test_straggler_detection():
    wd = tempfile.mkdtemp()
    coord = Coordinator(wd, n_controllers=3)
    try:
        t = 100.0
        for step in range(8):
            for h in (0, 1):
                coord.heartbeat(h, step, t)
            t += 1.0
        coord.heartbeat(0, 8, t)          # host 1 goes quiet
        coord.heartbeat(0, 9, t + 1)
        coord.heartbeat(0, 10, t + 2)
        lag = coord.stragglers(now=t + 3.5, hosts=[0, 1])
        assert lag == [1]                 # host0 lag 1.5 < 3x median(1.0)
    finally:
        coord.destroy()


def test_elastic_restore_to_new_mesh():
    """Manifest is mesh-agnostic: save under one mesh, restore under another
    sharding layout (elastic rescale path)."""
    cfg = get("smollm_135m", smoke=True)
    mesh = make_host_mesh()
    wd = tempfile.mkdtemp()
    try:
        from repro.launch import steps as S
        init_fn, st_sh = S.make_init_fn(cfg, mesh)
        state = init_fn(jax.random.PRNGKey(0))
        store = NezhaCheckpointStore(f"{wd}/ck")
        store.save(1, jax.tree.map(np.asarray, state))
        # "rescale": restore into a fresh mesh (same devices here, but the
        # path exercises manifest-driven resharding end-to-end)
        mesh2 = make_host_mesh()
        init2, st_sh2 = S.make_init_fn(cfg, mesh2)
        tmpl = S.abstract_state(cfg)
        host_tree, step = store.restore(tmpl)
        resharded = jax.tree.map(lambda a, sh: jax.device_put(a, sh),
                                 host_tree, st_sh2)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(resharded)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        store.close()
    finally:
        shutil.rmtree(wd, ignore_errors=True)


def test_pipeline_restart_determinism():
    cfg = get("smollm_135m", smoke=True)
    p1 = TokenPipeline(cfg, SHAPE, seed=3, start_step=0)
    b5 = p1.batch_for_step(5)
    p1.close()
    p2 = TokenPipeline(cfg, SHAPE, seed=3, start_step=5)
    b5b = p2.batch_for_step(5)
    p2.close()
    assert np.array_equal(b5["tokens"], b5b["tokens"])
    assert np.array_equal(b5["labels"], b5b["labels"])
