"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + one train step on CPU, assert output shapes + no NaNs;
plus prefill/decode == full-forward consistency for every cache layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.models import forward, init_cache, init_params, lm_loss
from repro.train.optimizer import adamw_update, init_opt_state

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16, extra=0):
    if cfg.input_kind == "embeds":
        x = jax.random.normal(KEY, (B, S + extra, cfg.d_model),
                              jnp.dtype(cfg.param_dtype))
    else:
        x = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab_size)
    labels = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab_size)
    return x, labels


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get(arch, smoke=True)
    params = init_params(KEY, cfg)
    B, S = 2, 16
    x, labels = _inputs(cfg, B, S)
    logits, _ = forward(params, x, cfg, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    loss = lm_loss(logits, labels)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates(arch):
    cfg = get(arch, smoke=True)
    params = init_params(KEY, cfg)
    m, v = init_opt_state(params)
    x, labels = _inputs(cfg)

    def loss_fn(p):
        logits, _ = forward(p, x, cfg, mode="train")
        return lm_loss(logits, labels)

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    new_p, m, v, gnorm = adamw_update(params, grads, m, v,
                                      jnp.zeros((), jnp.int32))
    assert np.isfinite(float(loss0)) and np.isfinite(float(gnorm))
    loss1 = loss_fn(new_p)
    assert float(loss1) < float(loss0)  # one step of AdamW must descend


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_prefill_decode_matches_forward(arch, layout):
    cfg = get(arch, smoke=True).replace(param_dtype="float32",
                                        kv_block_size=8)
    params = init_params(KEY, cfg)
    B, S = 2, 16
    x, _ = _inputs(cfg, B, S, extra=1)
    ref, _ = forward(params, x, cfg, mode="train")
    cache = init_cache(cfg, B, 32, layout)
    pre, cache = forward(params, x[:, :S], cfg, mode="prefill", caches=cache)
    np.testing.assert_allclose(np.asarray(pre, np.float32),
                               np.asarray(ref[:, :S], np.float32),
                               rtol=3e-4, atol=3e-4)
    dec, _ = forward(params, x[:, S:S + 1], cfg, mode="decode", caches=cache,
                     pos=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(ref[:, S], np.float32),
                               rtol=3e-4, atol=3e-4)


def test_decode_per_sequence_positions():
    """Ragged decode (continuous batching): per-seq pos vector must match
    per-seq scalar decode."""
    cfg = get("smollm_135m", smoke=True).replace(param_dtype="float32",
                                                 kv_block_size=8)
    params = init_params(KEY, cfg)
    B, S = 3, 16  # prefill length must be a multiple of kv_block_size
    x, _ = _inputs(cfg, B, S + 4)
    cache = init_cache(cfg, B, 32, "paged")
    _, cache = forward(params, x[:, :S], cfg, mode="prefill", caches=cache)
    pos = jnp.array([S, S, S], jnp.int32)
    step_tok = x[:, S:S + 1]
    ragged, _ = forward(params, step_tok, cfg, mode="decode", caches=cache,
                        pos=pos)
    scalar, _ = forward(params, step_tok, cfg, mode="decode", caches=cache,
                        pos=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(scalar),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_match_family_scale():
    full = get("smollm_135m")
    n = full.param_count()
    assert 120e6 < n < 150e6, n  # "135M" within tolerance
    q3 = get("qwen3_8b").param_count()
    assert 7e9 < q3 < 9e9, q3
    moe = get("olmoe_1b_7b")
    assert moe.active_param_count() < 0.4 * moe.param_count()
