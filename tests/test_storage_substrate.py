"""Unit tests for the storage substrate: ValueLog, MiniLSM, SortedStore."""
import os
import tempfile

import pytest
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from repro.testing.minihyp import (HealthCheck, given, settings,
                                       strategies as st)

from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM
from repro.core.storage import SortedStore
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog


def test_valuelog_roundtrip_and_offsets():
    wd = tempfile.mkdtemp()
    m = Metrics()
    vl = ValueLog(os.path.join(wd, "v.log"), m)
    offs = []
    for i in range(50):
        e = LogEntry(2, i + 1, KIND_PUT, f"k{i}".encode(), bytes([i]) * 100)
        offs.append(vl.append(e))
    for i in (0, 25, 49):
        e = vl.read_at(offs[i])
        assert e.index == i + 1 and e.value == bytes([i]) * 100
    scanned = list(vl.scan())
    assert len(scanned) == 50
    assert [o for o, _ in scanned] == offs
    vl.truncate_to(offs[30])
    assert len(list(vl.scan())) == 30
    vl.delete()


def test_valuelog_recovery_after_reopen():
    wd = tempfile.mkdtemp()
    path = os.path.join(wd, "v.log")
    vl = ValueLog(path, Metrics())
    vl.append(LogEntry(1, 1, KIND_PUT, b"a", b"xyz"))
    vl.close()
    vl2 = ValueLog(path, Metrics())
    entries = list(vl2.scan())
    assert len(entries) == 1 and entries[0][1].value == b"xyz"
    off = vl2.append(LogEntry(1, 2, KIND_PUT, b"b", b"w"))
    assert vl2.read_at(off).key == b"b"
    vl2.delete()


def test_minilsm_flush_compaction_and_reads():
    wd = tempfile.mkdtemp()
    m = Metrics()
    db = MiniLSM(wd, m, wal=True, memtable_limit=4 << 10, l0_limit=2)
    for i in range(200):
        db.put(f"k{i:04d}".encode(), bytes([i % 256]) * 64)
    assert db.compaction_count > 0
    assert db.get(b"k0042") == bytes([42]) * 64
    assert db.get(b"nope") is None
    out = db.scan(b"k0050", b"k0059")
    assert [k for k, _ in out] == [f"k{i:04d}".encode() for i in range(50, 60)]
    # newest version wins across levels
    db.put(b"k0042", b"NEW")
    assert db.get(b"k0042") == b"NEW"
    assert m.write_bytes["wal"] > 0 and m.write_bytes["flush"] > 0
    assert m.write_bytes["compaction"] > 0
    db.destroy()


def test_minilsm_wal_recovery():
    wd = tempfile.mkdtemp()
    db = MiniLSM(wd, Metrics(), wal=True, memtable_limit=1 << 20)
    for i in range(20):
        db.put(f"k{i}".encode(), f"v{i}".encode())
    db.close()  # memtable lost, WAL survives
    db2 = MiniLSM(wd, Metrics(), wal=True, memtable_limit=1 << 20)
    replayed = db2.recover()
    assert replayed == 20
    assert db2.get(b"k7") == b"v7"
    db2.destroy()


@settings(max_examples=25, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(st.lists(st.tuples(st.binary(min_size=1, max_size=8),
                          st.binary(min_size=0, max_size=64)),
                min_size=1, max_size=120))
def test_minilsm_behaves_like_dict(ops):
    """Property: MiniLSM == last-writer-wins dict, incl. after flush."""
    wd = tempfile.mkdtemp()
    db = MiniLSM(wd, Metrics(), wal=False, memtable_limit=512, l0_limit=2)
    model = {}
    for k, v in ops:
        db.put(k, v)
        model[k] = v
    for k, v in model.items():
        assert db.get(k) == v
    assert db.scan(b"", b"\xff" * 9) == sorted(model.items())
    db.destroy()


def test_sorted_store_build_load_scan():
    wd = tempfile.mkdtemp()
    m = Metrics()
    s = SortedStore(wd, m, gen=1)
    items = [(f"k{i:03d}".encode(),
              LogEntry(1, i + 1, KIND_PUT, f"k{i:03d}".encode(),
                       bytes([i]) * 32))
             for i in range(100)]
    s.build(iter(items), last_index=100, last_term=1)
    assert s.get(b"k050") == bytes([50]) * 32
    assert s.get(b"zzz") is None
    got = s.scan(b"k010", b"k019")
    assert len(got) == 10 and got[0][0] == b"k010"
    # reload from disk
    s2 = SortedStore(wd, Metrics(), gen=1)
    assert s2.load()
    assert s2.last_index == 100
    assert s2.get(b"k099") == bytes([99]) * 32
    s2.destroy()
