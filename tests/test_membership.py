"""Self-healing membership: single-server config changes, learners,
live replacement.

Load-bearing claims under test:

  * Single-server changes only: a config entry is effective on append,
    commits under its OWN quorum, at most one is in flight, and a
    multi-voter jump is refused outright (adjacent configs must share a
    majority, so there is never a moment two disjoint quorums exist).
  * Learners replicate (InstallSnapshot + run shipping) but never vote,
    never campaign, and never count toward any quorum; the leader
    auto-promotes a learner once its applied index is within
    promote_lag of the commit index.
  * An uncommitted config entry rolls back when the log suffix holding
    it is truncated — including across a restart, where the entry is
    re-adopted from the durable log first and THEN truncated away.
  * A removed node with a stale config can never win an election: live
    voters answer its RequestVote with total silence (no term adoption),
    so its runaway term cannot disturb the live quorum either.
  * SimNet kills a removed address completely: queued mail is destroyed
    (counted in dropped_msgs) and future mail in either direction drops.
  * Client routing: reads pinned to a removed node raise
    NodeRemovedError; session reads route around removed nodes.
  * Cluster.replace_node restores the original voter count after a hard
    kill, with the learner's catch-up bytes visible via Metrics.on_ship,
    and the cluster manifest makes the healed shape recoverable.
  * run_membership_crashpoint: killing the whole fleet at any I/O index
    inside the config-change commit window recovers with no acked-write
    loss, ONE committed config, and one leader per term across the
    crash boundary.

Every crash-sweep failure reproduces from {seed, crash_index, mode}
alone — assertion messages carry the exact call to paste.
"""
import os

import pytest

from repro.core.client import LINEARIZABLE, NodeRemovedError
from repro.core.cluster import Cluster
from repro.core.raft import LEADER
from repro.core.simnet import SimNet
from repro.core.workload import (ChaosSchedule, FaultEvent, WorkloadSpec,
                                 run_membership_crashpoint, run_workload)

pytestmark = pytest.mark.membership

MEMBER_SWEEP_N = int(os.environ.get("MEMBER_SWEEP_N", "36"))


def _mk(tmp_path, sub="c", n=3, seed=5, **kw):
    c = Cluster(n=n, engine="nezha", workdir=str(tmp_path / sub), seed=seed,
                engine_kwargs={"gc_threshold": 4096}, **kw)
    c.elect()
    return c


def _close(c):
    for e in c.engines:
        if e is not None:
            e.close()


def _settle(c, max_ticks=8000):
    for _ in range(max_ticks):
        ld = c.leader()
        if ld is not None and all(
                nd is None or nd.last_applied >= ld.commit_index
                for i, nd in enumerate(c.nodes)
                if i in (set(ld.voters) | set(ld.learners))):
            return ld
        c.tick()
    raise TimeoutError("cluster never settled")


# --------------------------------------------------------- happy path
def test_add_promote_remove_cycle(tmp_path):
    """Join a learner, watch catch-up promote it, retire a founder —
    the config index advances once per change and data survives."""
    c = _mk(tmp_path)
    for i in range(30):
        c.put(b"k%04d" % i, b"v%04d" % i)
    c.force_gc()
    c.drain_shipping(2000)
    ship0 = sum(m.total_ship_bytes() for m in c.metrics)
    new = c.add_node()
    assert new == 3
    ld = c.leader()
    assert new in ld.learners and new not in ld.voters
    assert c.wait_promoted(new)
    ld = c.leader()
    assert new in ld.voters and new not in ld.learners
    # the learner caught up over the wire: snapshot and/or run shipping
    assert sum(m.total_ship_bytes() for m in c.metrics) > ship0
    c.remove_node(1)
    ld = c.leader()
    assert sorted(ld.voters) == [0, 2, 3]
    assert 1 in c.removed and c.nodes[1] is None
    for i in range(30, 45):
        c.put(b"k%04d" % i, b"v%04d" % i)
    assert len(c.scan(b"k", b"l")) == 45
    ev = ld.metrics.membership_events
    assert ev["promote"] >= 1 and ev["config_proposed"] >= 3
    _close(c)


def test_replace_node_after_hard_kill(tmp_path):
    """The smoke-gate cycle: kill -9 a voter, replace it, quorum is back
    at three voters and scans are byte-equal across the final voter set."""
    c = _mk(tmp_path, seed=11)
    for i in range(24):
        c.put(b"k%04d" % i, b"v%04d" % i)
    c.force_gc()
    c.crash(1)
    new = c.replace_node(1)
    ld = c.leader()
    assert sorted(ld.voters) == sorted({0, 2, new})
    for i in range(24, 36):
        c.put(b"k%04d" % i, b"v%04d" % i)
    ld = _settle(c)
    scans = [c.engines[i].scan(b"k", b"l") for i in sorted(ld.voters)]
    assert all(s == scans[0] for s in scans[1:])
    _close(c)


def test_graceful_leader_self_removal_transfers_first(tmp_path):
    """remove_node(leader) hands leadership off (TimeoutNow) before the
    removal commits; the deposed id steps down and the history never
    shows two leaders for one term."""
    c = _mk(tmp_path, seed=3)
    for i in range(10):
        c.put(b"k%04d" % i, b"v%04d" % i)
    old = c.elect().nid
    c.remove_node(old)
    ld = c.leader()
    assert ld is not None and ld.nid != old
    assert old not in ld.voters and old in c.removed
    c.put(b"after", b"removal")
    hist = []
    for nd in c.nodes:
        if nd is not None:
            hist.extend(nd.leadership_history)
    by_term = {}
    for term, nid in hist:
        assert by_term.setdefault(term, nid) == nid, \
            f"two leaders for term {term}"
    assert ld.metrics.membership_events.get("transfer", 0) + \
        c.metrics[old].membership_events.get("transfer", 0) >= 1
    _close(c)


# ------------------------------------------------- config-change safety
def test_reject_second_inflight_change(tmp_path):
    """At most one config change in flight: a second proposal is refused
    until the first commits, then accepted."""
    c = _mk(tmp_path, auto_promote=False)
    ld = c.leader()
    idx = ld.propose_add_learner(3)
    assert idx is not None and idx > ld.commit_index
    assert ld.propose_remove(2) is None          # refused: one in flight
    for _ in range(2000):
        if ld.config_index <= ld.commit_index:
            break
        c.tick()
    assert ld.config_index <= ld.commit_index
    assert ld.propose_remove(2) is not None      # accepted once committed
    _close(c)


def test_multi_voter_jump_refused(tmp_path):
    """Adjacent configs must differ by at most one voter — the overlap
    argument that makes joint consensus unnecessary."""
    c = _mk(tmp_path)
    ld = c.leader()
    with pytest.raises(ValueError):
        ld.propose_config(voters=(0,), learners=())   # drops two at once
    _close(c)


def test_config_commits_under_its_own_quorum(tmp_path):
    """Effective on append: a promote entry (3 voters -> 4) needs THREE
    acks to commit.  With two voters down it must stall; reviving one
    completes it — and any majority of {0,1,2,3} overlaps any majority
    of {0,1,2}, so no split-brain window exists in between."""
    c = _mk(tmp_path, auto_promote=False)
    new = c.add_node()
    c.crash(1)
    c.crash(2)
    ld = c.leader()
    assert ld.propose_promote(new) is not None
    c.tick(600)
    assert ld.config_index > ld.commit_index     # 2 of 4 acks: stalled
    assert new in ld.voters                      # ...but already in effect
    c.restart(1)
    for _ in range(4000):
        if ld.config_index <= ld.commit_index:
            break
        c.tick()
    assert ld.config_index <= ld.commit_index    # 3 of 4: committed
    _close(c)


def test_uncommitted_config_rolls_back_across_restart(tmp_path):
    """An isolated leader appends a removal config (effective at once),
    crashes, restarts (the durable log re-adopts the entry), and is then
    truncated by the new leader — the config must roll back with the
    suffix, on disk and in memory."""
    c = _mk(tmp_path, seed=9, sync=True)
    for i in range(6):
        c.put(b"k%04d" % i, b"v%04d" % i)
    old = c.elect()
    onid = old.nid
    c.isolate(onid)
    assert old.propose_remove((onid + 1) % 3) is not None
    assert len(old.voters) == 2                  # in effect immediately
    c.tick(400)                                  # but never committed
    assert old.config_index > old.commit_index
    c.crash(onid)
    # the survivors elect and commit new entries the stale suffix loses to
    for _ in range(4000):
        ld = c.leader()
        if ld is not None and ld.nid != onid:
            break
        c.tick()
    c.put(b"winner", b"entry")
    c.restart(onid)
    back = c.nodes[onid]
    assert len(back.voters) == 2                 # durable log re-adopted it
    c.heal()
    for _ in range(6000):
        if back.config_index <= back.commit_index and len(back.voters) == 3:
            break
        c.tick()
    assert sorted(back.voters) == [0, 1, 2]      # rolled back with truncation
    assert back.config_index == 0
    _close(c)


def test_partitioned_removed_node_cannot_win_election(tmp_path):
    """A node removed while partitioned still holds the old 3-voter
    config.  When it comes back it campaigns forever — and must be met
    with total silence: it never wins, and its runaway term never
    disturbs the live quorum (no term adoption on refusal)."""
    c = _mk(tmp_path, seed=7)
    for i in range(8):
        c.put(b"k%04d" % i, b"v%04d" % i)
    c.isolate(2)
    zombie = c.nodes[2]
    ld = c.leader()
    for _ in range(4000):
        if ld.propose_remove(2) is not None and \
                ld.config_index <= ld.commit_index and 2 not in ld.voters:
            break
        c.tick()
        ld = c.leader()
    assert 2 not in ld.voters
    assert sorted(zombie.voters) == [0, 1, 2]    # never saw its removal
    term_before = ld.current_term
    c.heal()                                     # let the zombie talk
    for _ in range(3000):
        c.tick()
    assert zombie.role != LEADER
    assert zombie.current_term > term_before     # it kept trying...
    live = c.leader()
    assert live.nid != 2
    assert live.current_term == term_before      # ...and moved nothing
    c.put(b"still", b"live")                     # quorum undisturbed
    _close(c)


def test_learner_never_counts_toward_quorum(tmp_path):
    """Three voters + one learner: with two voters down the cluster must
    refuse writes even though the learner is healthy and caught up."""
    c = _mk(tmp_path, auto_promote=False)
    new = c.add_node()
    c.put(b"pre", b"crash")
    c.crash(1)
    c.crash(2)
    with pytest.raises(TimeoutError):
        c.put(b"no", b"quorum", max_ticks=400)
    c.restart(1)                                 # 2 of 3 voters again
    c.put(b"yes", b"quorum")
    ld = c.leader()
    assert new in ld.learners
    _close(c)


def test_learner_is_not_offered_votes_and_does_not_campaign(tmp_path):
    c = _mk(tmp_path, auto_promote=False)
    new = c.add_node()
    lr = c.nodes[new]
    c.kill_leader()
    for _ in range(3000):
        c.tick()
        assert lr.role != LEADER
        if c.leader() is not None:
            break
    assert c.leader() is not None                # voters elected around it
    _close(c)


# --------------------------------------------------------- substrate
def test_simnet_removed_address_is_dead(tmp_path):
    net = SimNet([0, 1, 2], seed=1)
    for _ in range(5):
        net.send(0, 2, "hello")
    assert len(net._q[2]) == 5
    d0 = net.dropped_msgs
    net.remove_node(2)
    assert net.dropped_msgs == d0 + 5            # queued mail destroyed
    assert net._q[2] == []
    net.send(0, 2, "late")                       # to the dead address
    net.send(2, 0, "zombie")                     # and from it
    assert net.dropped_msgs == d0 + 7
    net.time += 100
    assert net.deliver(2) == []
    net.add_node(2)                              # a fresh joiner reuses it
    net.send(0, 2, "fresh")
    assert len(net._q[2]) == 1


def test_health_report_shows_roles_and_config(tmp_path):
    c = _mk(tmp_path, auto_promote=False)
    new = c.add_node()
    hr = c.health_report()
    roles = {n["node"]: n["membership"] for n in hr["nodes"]}
    assert roles[0] == roles[1] == roles[2] == "voter"
    assert roles[new] == "learner"
    assert hr["membership"]["learners"] == [new]
    assert hr["net"]["removed"] == []
    c.leader().auto_promote = True               # promotion is leader-driven
    assert c.wait_promoted(new)
    c.remove_node(0)
    hr = c.health_report()
    roles = {n["node"]: n["membership"] for n in hr["nodes"]}
    assert roles[0] == "removed"
    assert hr["membership"]["removed"] == [0]
    assert 0 in hr["net"]["removed"]
    assert hr["membership"]["config_index"] > 0
    _close(c)


def test_client_routing_around_removed_nodes(tmp_path):
    """Pinned reads on a removed node fail fast with NodeRemovedError;
    session reads silently re-route; the put retry loop keeps working
    right through a membership change."""
    c = _mk(tmp_path, seed=13)
    s = c.session()
    for i in range(12):
        c.put(b"k%04d" % i, b"v%04d" % i)
    assert c.get(b"k0003", "session", session=s) == b"v0003"
    c.remove_node(2)
    with pytest.raises(NodeRemovedError):
        c.get(b"k0003", node=2)
    with pytest.raises(NodeRemovedError):
        c.scan(b"k", b"l", node=2)
    for i in range(12, 20):                      # puts retarget the leader
        c.put(b"k%04d" % i, b"v%04d" % i)
    assert c.get(b"k0015", "session", session=s) == b"v0015"
    assert len(c.scan(b"k", b"l")) == 20
    _close(c)


def test_manifest_makes_healed_shape_recoverable(tmp_path):
    """After replace_node, a polite shutdown + Cluster(recover=True)
    boots the healed shape: the removed id stays removed, the new voter
    comes back, and every acked write is readable."""
    wd = str(tmp_path / "c")
    c = Cluster(n=3, engine="nezha", workdir=wd, seed=2, sync=True,
                engine_kwargs={"gc_threshold": 4096})
    c.elect()
    items = {b"k%04d" % i: b"v%04d" % i * 10 for i in range(16)}
    for k, v in items.items():
        c.put(k, v)
    c.force_gc()
    new = c.replace_node(1)
    for k in list(items):
        items[k + b"x"] = b"post"
        c.put(k + b"x", b"post")
    _settle(c)
    _close(c)
    rec = Cluster(n=c.n, engine="nezha", workdir=wd, seed=8, recover=True,
                  engine_kwargs={"gc_threshold": 4096})
    assert rec.removed == {1} and rec.nodes[1] is None
    ld = rec.elect()
    assert sorted(ld.voters) == sorted({0, 2, new})
    rec.put(b"zz-liveness", b"alive")
    for k, v in items.items():
        assert rec.get(k) == v
    rec.destroy()


# ------------------------------------------------------------- chaos
def test_chaos_replace_random_node_deterministic(tmp_path):
    """The replace_random_node action heals mid-workload with zero
    checker violations, and the same seed picks the same victim."""
    def one(sub):
        c = _mk(tmp_path, sub, seed=13)
        sched = ChaosSchedule(
            [FaultEvent(0.3, "replace_random_node", recovery=True)], seed=13)
        rep = run_workload(c, WorkloadSpec(n_ops=120, n_keys=50, seed=13,
                                           virtual_time=True), chaos=sched)
        assert rep.violations == []
        ld = c.leader()
        assert len(ld.voters) == 3 and len(c.removed) == 1
        _close(c)
        return rep.timeline

    a, b = one("a"), one("b")
    assert a == b


# --------------------------------------- config-change-window crashpoints
def test_membership_record_run_is_deterministic(tmp_path):
    a = run_membership_crashpoint(str(tmp_path / "a"), seed=5)
    b = run_membership_crashpoint(str(tmp_path / "b"), seed=5)
    assert not a["crashed"] and a["recovered_ok"], \
        (a["violations"][:3], a["audit"][:3])
    assert a["ops"] == b["ops"]
    assert a["member_window"] == b["member_window"]
    assert a["voters"] == [0, 2, 3]              # healed shape


@pytest.mark.crashpoint
def test_config_change_window_crashpoint_sweep(tmp_path):
    """Kill the WHOLE fleet at >= MEMBER_SWEEP_N I/O indices spread
    across the add-learner -> promote -> remove-voter window, cycling
    torn/drop semantics.  Every recovery must keep every acked write,
    converge byte-equal, agree on ONE committed config, and never show
    two leaders for one term across the crash boundary."""
    rec = run_membership_crashpoint(str(tmp_path / "record"), seed=5)
    assert rec["recovered_ok"] and not rec["crashed"]
    lo, hi = rec["member_window"]
    assert hi - lo >= MEMBER_SWEEP_N, "window too narrow to sweep"
    failures = []
    for k in range(MEMBER_SWEEP_N):
        ci = lo + (hi - lo) * k // MEMBER_SWEEP_N
        mode = ("torn", "drop")[k % 2]
        r = run_membership_crashpoint(str(tmp_path / f"p{k}"), seed=5,
                                      crash_index=ci, mode=mode)
        assert r["crashed"], f"crash index {ci} never fired"
        if not r["recovered_ok"]:
            failures.append((ci, mode, r["double_leaders"],
                             r["violations"][:2], r["audit"][:2],
                             r["converged"], r["one_config"]))
    assert not failures, (
        f"{len(failures)}/{MEMBER_SWEEP_N} config-window crash points "
        f"failed: {failures[:4]} — reproduce any with "
        f"run_membership_crashpoint(dir, seed=5, crash_index=CI, "
        f"mode=MODE)")
