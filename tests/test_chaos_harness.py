"""Chaos + tail-latency workload harness (repro.core.workload).

What is pinned here, per the harness's own determinism contract:

  * chaos-schedule determinism — the same {seed, schedule} produces the
    IDENTICAL fault timeline AND the identical SimNet delivery order
    (message-for-message), while a different chaos seed diverges.  This
    is the property that makes every BENCH_fig_tail chaos row replayable
    from the recorded {seed, schedule} alone.
  * histogram math (minihyp properties) — LatencyHistogram quantiles are
    nearest-rank within one log bucket of the exact sample quantile, and
    merge() is bucket-exact: merging two histograms equals the histogram
    of the concatenated samples.
  * checker self-test — hand-built histories with a known stale read,
    lost write, never-written value, session monotonicity break and scan
    divergence are each flagged; clean histories (including the
    inclusive-[lo,hi] scan edge) pass.
  * the supporting surfaces the harness rides on: Metrics.snapshot() /
    delta() phase accounting, SimNet per-link injection + fork_rng,
    Cluster.health_report().

`pytest -m chaos` (make chaos) additionally runs a fuller generated
schedule — kills, isolation, lossy windows and GC storms against a real
GC-cycling cluster — and asserts the zero-violation + phase-accounting
invariants end to end.
"""
import json
import math
import tempfile

import pytest

from repro.core.client import LINEARIZABLE, SESSION
from repro.core.cluster import Cluster
from repro.core.metrics import LatencyHistogram, Metrics
from repro.core.simnet import SimNet
from repro.core.workload import (ChaosSchedule, FaultEvent, OpRecord,
                                 Tenant, WorkloadSpec, check_history,
                                 run_workload)
from repro.testing.minihyp import given, settings
from repro.testing.minihyp import strategies as st


def make_cluster(n=3, seed=4, **engine_kw):
    wd = tempfile.mkdtemp(prefix="chaosharness_")
    kw = {"gc_threshold": 1 << 60}
    kw.update(engine_kw)
    return Cluster(n=n, engine="nezha", workdir=wd, seed=seed,
                   engine_kwargs=kw)


# ----------------------------------------------------- chaos determinism
def _traced_run(chaos_seed, cluster_seed=4, n_ops=120):
    c = make_cluster(seed=cluster_seed)
    c.net.enable_trace()
    spec = WorkloadSpec(rate=5000.0, n_ops=n_ops, n_keys=60, vsize=64,
                        seed=3, tenants=(Tenant("t", 1.0, "A"),))
    chaos = ChaosSchedule.generate(chaos_seed, n_cycles=2)
    rep = run_workload(c, spec, chaos)
    return rep, list(c.net.trace)


def test_same_seed_same_timeline_and_delivery_order():
    rep1, trace1 = _traced_run(chaos_seed=11)
    rep2, trace2 = _traced_run(chaos_seed=11)
    assert rep1.timeline == rep2.timeline
    assert rep1.timeline, "schedule fired no faults"
    assert trace1 == trace2, "SimNet delivery order diverged on same seed"
    assert rep1.violations == [] and rep2.violations == []


def test_different_chaos_seed_diverges():
    rep1, _ = _traced_run(chaos_seed=11)
    rep2, _ = _traced_run(chaos_seed=12)
    assert rep1.chaos["schedule"] != rep2.chaos["schedule"]
    assert rep1.timeline != rep2.timeline


def test_generate_is_a_pure_function_of_seed():
    a = ChaosSchedule.generate(5, n_cycles=3).record()
    b = ChaosSchedule.generate(5, n_cycles=3).record()
    c = ChaosSchedule.generate(6, n_cycles=3).record()
    assert a == b
    assert a["schedule"] != c["schedule"]
    # every generated cycle pairs a fault with its recovery marker
    assert sum(e["recovery"] for e in a["schedule"]) == 3


def test_kill_and_recover_timeline_names_the_same_victim():
    reps = []
    for _ in range(2):
        c = make_cluster(seed=9)
        spec = WorkloadSpec(rate=5000.0, n_ops=100, n_keys=50, vsize=64,
                            seed=1, tenants=(Tenant("t", 1.0, "A"),))
        reps.append(run_workload(c, spec,
                                 ChaosSchedule.kill_and_recover(seed=9)))
    t1, t2 = reps[0].timeline, reps[1].timeline
    assert t1 == t2
    assert [e["action"] for e in t1] == ["kill_leader", "restart"]
    assert t1[0]["detail"] == t1[1]["detail"]   # restart revives the victim
    assert reps[0].violations == []


def test_unknown_chaos_action_rejected():
    with pytest.raises(ValueError):
        FaultEvent(0.5, "meteor_strike")


# ------------------------------------------------- histogram properties
@settings(max_examples=25)
@given(st.lists(st.integers(min_value=1, max_value=10_000_000),
                min_size=1, max_size=200),
       st.sampled_from([0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0]))
def test_hist_quantile_within_one_bucket_of_exact(samples, q):
    h = LatencyHistogram()
    for s in samples:
        h.record(s)
    exact = sorted(samples)[max(1, math.ceil(q * len(samples))) - 1]
    got = h.quantile(q)
    # reported as the bucket's upper edge: >= the exact sample, and no
    # more than one bucket (a growth factor) above it
    assert got >= exact * (1 - 1e-9)
    assert got <= exact * h.growth ** 2


@settings(max_examples=25)
@given(st.lists(st.integers(min_value=1, max_value=1_000_000),
                min_size=0, max_size=100),
       st.lists(st.integers(min_value=1, max_value=1_000_000),
                min_size=0, max_size=100))
def test_hist_merge_equals_concatenation(xs, ys):
    ha, hb, hcat = (LatencyHistogram() for _ in range(3))
    for x in xs:
        ha.record(x)
        hcat.record(x)
    for y in ys:
        hb.record(y)
        hcat.record(y)
    ha.merge(hb)
    assert dict(ha.counts) == dict(hcat.counts)
    assert ha.n == hcat.n and ha.total == hcat.total
    assert ha.max_seen == hcat.max_seen
    if ha.n == 0:
        # both inputs empty: quantile refuses rather than inventing 0
        with pytest.raises(ValueError):
            ha.quantile(0.5)
        return
    for q in (0.5, 0.99, 0.999):
        assert ha.quantile(q) == hcat.quantile(q)


def test_hist_quantile_empty_raises():
    h = LatencyHistogram()
    with pytest.raises(ValueError, match="empty histogram"):
        h.quantile(0.99)
    # summary() stays total: all-zero digest, explicit n=0
    assert h.summary() == {"n": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                           "p99": 0.0, "p999": 0.0, "max": 0.0}


def test_hist_merge_rejects_geometry_mismatch():
    with pytest.raises(ValueError) as ei:
        LatencyHistogram(min_value=0.1).merge(LatencyHistogram(min_value=1))
    # the message names BOTH geometries so the mismatch is debuggable
    assert "min_value=1" in str(ei.value) and "min_value=0.1" in str(ei.value)
    with pytest.raises(ValueError, match="growth"):
        LatencyHistogram(growth=1.5).merge(LatencyHistogram(growth=2.0))


# ------------------------------------------------- checker self-test
K, V1, V2, V3 = b"wk00000001", b"v-one", b"v-two", b"v-ghost"


def test_checker_clean_history_passes():
    hist = [OpRecord("put", K, V1),
            OpRecord("get", K, V1),
            OpRecord("put", K, V2),
            OpRecord("get", K, V2, tier=SESSION, session=0),
            OpRecord("scan", value=[(K, V2)], lo=b"wk", hi=K)]
    assert check_history(hist) == []


def test_checker_flags_stale_read():
    hist = [OpRecord("put", K, V1), OpRecord("put", K, V2),
            OpRecord("get", K, V1)]
    (v,) = check_history(hist)
    assert "stale read" in v


def test_checker_flags_lost_write():
    hist = [OpRecord("put", K, V1), OpRecord("get", K, None)]
    (v,) = check_history(hist)
    assert "lost write" in v


def test_checker_flags_never_written_value():
    hist = [OpRecord("put", K, V1), OpRecord("get", K, V3)]
    (v,) = check_history(hist)
    assert "never written" in v


def test_checker_session_guarantees():
    # read-your-writes: the session wrote, then read nothing
    (v,) = check_history([OpRecord("put", K, V1, session=0),
                          OpRecord("get", K, None, tier=SESSION, session=0)])
    assert "lost write" in v
    # monotonic reads: saw write[1], then went back to write[0]
    (v,) = check_history([OpRecord("put", K, V1), OpRecord("put", K, V2),
                          OpRecord("get", K, V2, tier=SESSION, session=0),
                          OpRecord("get", K, V1, tier=SESSION, session=0)])
    assert "went backwards" in v
    # a DIFFERENT session has no floor: the same stale value is legal
    assert check_history([
        OpRecord("put", K, V1), OpRecord("put", K, V2),
        OpRecord("get", K, V2, tier=SESSION, session=0),
        OpRecord("get", K, V1, tier=SESSION, session=1)]) == []


def test_checker_scan_divergence_and_inclusive_bounds():
    k2 = b"wk00000002"
    hist = [OpRecord("put", K, V1), OpRecord("put", k2, V2),
            # engine scans include BOTH bounds: [K, k2] must return both
            OpRecord("scan", value=[(K, V1), (k2, V2)], lo=K, hi=k2)]
    assert check_history(hist) == []
    (v,) = check_history([OpRecord("put", K, V1), OpRecord("put", k2, V2),
                          OpRecord("scan", value=[(K, V1)], lo=K, hi=k2)])
    assert "diverged" in v and "missing" in v


# ------------------------------------- supporting surfaces the harness uses
def test_metrics_snapshot_delta():
    m = Metrics()
    m.write_bytes["wal"] += 100
    m.fsyncs += 2
    snap = m.snapshot()
    m.write_bytes["wal"] += 50
    m.read_tiers["lease"] += 3
    m.fsyncs += 1
    d = m.delta(snap)
    assert d["write_bytes"] == {"wal": 50}       # movement only
    assert d["read_tiers"] == {"lease": 3}
    assert d["fsyncs"] == 1
    assert d["read_bytes"] == {}                 # untouched category
    # no baseline => lifetime totals; snapshot stays frozen
    assert m.delta()["write_bytes"] == {"wal": 150}
    assert snap["write_bytes"] == {"wal": 100}


def test_simnet_per_link_injection():
    net = SimNet([0, 1, 2], seed=1, min_delay=1, max_delay=1)
    net.set_link(0, 1, min_delay=50, max_delay=50)
    net.send(0, 1, "slow")
    net.send(0, 2, "fast")
    for _ in range(2):
        net.tick()
    assert [m for _, m in net.deliver(2)] == ["fast"]
    assert net.deliver(1) == []                  # still in flight
    for _ in range(49):
        net.tick()
    assert [m for _, m in net.deliver(1)] == ["slow"]

    net.set_link(0, 2, drop_prob=1.0)            # lossy single link
    before = net.dropped_msgs
    net.send(0, 2, "doomed")
    net.send(0, 1, "fine")                       # other link unaffected
    assert net.dropped_msgs == before + 1
    net.clear_link(0, 2)
    net.send(0, 2, "alive")
    assert net.dropped_msgs == before + 1

    with pytest.raises(ValueError):
        net.set_link(0, 1, min_delay=5)          # needs both bounds


def test_simnet_fork_rng_does_not_perturb_delivery():
    def delays(consume_fork):
        net = SimNet([0, 1], seed=7, min_delay=1, max_delay=9)
        out = []
        for i in range(20):
            if consume_fork:
                net.fork_rng(f"chaos:{i}").random()
            net.send(0, 1, i)
            q = net._q[1]
            out.append(q[-1][0] - net.time)
        return out

    assert delays(False) == delays(True)
    # and the fork itself is a pure function of (seed, tag)
    a = SimNet([0], seed=7).fork_rng("x").random()
    b = SimNet([0], seed=7).fork_rng("x").random()
    c = SimNet([0], seed=8).fork_rng("x").random()
    assert a == b != c


def test_cluster_health_report():
    c = make_cluster()
    c.put(b"k", b"v")
    ld = c.elect()
    hr = c.health_report()
    assert hr["leader"] == ld.nid
    assert len(hr["nodes"]) == 3
    assert all(n["up"] for n in hr["nodes"])
    json.dumps(hr)                               # scrapeable == JSON-able
    victim = next(i for i in range(3) if i != ld.nid)
    c.crash(victim)
    hr = c.health_report()
    assert hr["nodes"][victim]["up"] is False
    assert victim in hr["net"]["down"] or victim in list(hr["net"]["down"])


# -------------------------------------------------------- end-to-end runs
def test_workload_report_invariants_small_chaos_run():
    c = make_cluster(seed=6)
    spec = WorkloadSpec(rate=4000.0, n_ops=150, n_keys=80, vsize=64,
                        seed=2,
                        tenants=(Tenant("rw", 2.0, "A"),
                                 Tenant("ro", 1.0, "C", tier=SESSION)))
    rep = run_workload(c, spec, ChaosSchedule.kill_and_recover(seed=6))
    assert rep.violations == []
    assert sum(rep.phase_ops.values()) == spec.n_ops
    assert set(rep.phase_ops) == {"steady", "fault", "recovered"}
    assert rep.achieved_rate > 0
    assert rep.chaos["seed"] == 6 and len(rep.chaos["schedule"]) == 2
    for phase in rep.phase_ops:
        assert "fsyncs" in rep.phase_metrics[phase]
        assert "sent_msgs" in rep.phase_net[phase]
    json.dumps(rep.summary())


@pytest.mark.chaos
def test_full_chaos_schedule_zero_violations():
    """make chaos: generated kill/isolate/lossy/gc_storm schedule against
    a cluster that really GC-cycles, all three tiers live, checker on."""
    c = make_cluster(seed=14, gc_threshold=24 << 10, gc_batch=128,
                     level_fanout=2)
    spec = WorkloadSpec(rate=2500.0, n_ops=400, n_keys=150, vsize=256,
                        seed=5,
                        tenants=(Tenant("oltp", 2.0, "A"),
                                 Tenant("mix", 1.0, "F"),
                                 Tenant("scan", 1.0, "E", tier=SESSION)))
    chaos = ChaosSchedule.generate(14, n_cycles=3)
    rep = run_workload(c, spec, chaos)
    assert rep.violations == [], rep.violations[:5]
    assert len(rep.timeline) >= 3
    assert sum(rep.phase_ops.values()) == spec.n_ops
    # the artifact contract: the run is replayable from {seed, schedule}
    assert rep.chaos == chaos.record()
    json.dumps(rep.summary())
