"""Raft safety properties under hypothesis-generated fault schedules — our
executable analogue of the paper's TLA+ verification (§III-E):

  * Election Safety      — at most one leader per term
  * Log Matching         — same (index, term) => identical entries + prefix
  * Leader Completeness / State-Machine Safety — applied sequences are
    prefixes of one another across all nodes
"""
import os
import tempfile

import pytest
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from repro.testing.minihyp import (HealthCheck, given, settings,
                                       strategies as st)

from repro.core.cluster import Cluster
from repro.core.raft import LEADER


def run_schedule(engine, ops, seed, n=3):
    wd = tempfile.mkdtemp(prefix="raftprop_")
    kw = {"gc_threshold": 32 << 10} if engine == "nezha" else {}
    c = Cluster(n=n, engine=engine, workdir=wd, seed=seed, engine_kwargs=kw)
    crashed = set()
    key_i = 0
    try:
        c.elect()
        for op, arg in ops:
            if op == "put":
                ld = c.leader()
                if ld is not None:
                    key_i += 1
                    ld.client_put(f"k{key_i:04d}".encode(),
                                  bytes([arg]) * 64)
            elif op == "tick":
                c.tick(arg)
            elif op == "crash":
                tgt = arg % n
                if tgt not in crashed and len(crashed) < (n - 1) // 2 + 0:
                    # keep a majority alive so liveness holds
                    if len(crashed) < (n - 1) // 2:
                        c.crash(tgt)
                        crashed.add(tgt)
            elif op == "restart":
                tgt = arg % n
                if tgt in crashed:
                    c.restart(tgt)
                    crashed.discard(tgt)
            elif op == "partition":
                c.net.partition(arg % n, (arg + 1) % n)
            elif op == "heal":
                c.net.heal()
        # converge: heal everything, restart everyone, settle
        c.net.heal()
        for tgt in list(crashed):
            c.restart(tgt)
        c.tick(400)
        check_safety(c)
    finally:
        c.destroy()


def check_safety(c: Cluster):
    nodes = [n for n in c.nodes if n is not None]
    # Election safety: <= 1 leader per term
    by_term = {}
    for nd in nodes:
        for term, nid in nd.leadership_history:
            by_term.setdefault(term, set()).add(nid)
    for term, nids in by_term.items():
        assert len(nids) == 1, f"two leaders in term {term}: {nids}"
    def fp(e):
        """Entry fingerprint; header-only recovered entries (value=b'' with
        value_len set) compare by length — lazy hydration is still the same
        persisted entry."""
        vl = len(e.value) or getattr(e, "value_len", 0)
        return (e.term, e.key, vl)

    # Log matching on committed prefixes
    for a in nodes:
        for b in nodes:
            lo = max(a.snap_index, b.snap_index)
            hi = min(a.commit_index, b.commit_index)
            for idx in range(lo + 1, hi + 1):
                assert fp(a.entry_at(idx)) == fp(b.entry_at(idx)), \
                    f"log mismatch at {idx}"
    # State-machine safety: applied sequences agree on shared indices
    seqs = [[(i,) + fp(e)[1:] for i, e in nd.applied_log] for nd in nodes]
    seqs.sort(key=len)
    for i in range(len(seqs) - 1):
        a, b = seqs[i], seqs[i + 1]
        bi = {idx: rest for idx, *rest in b}
        for idx, *rest in a:
            if idx in bi:
                assert bi[idx] == rest, f"apply divergence at {idx}"


OP = st.one_of(
    st.tuples(st.just("put"), st.integers(0, 255)),
    st.tuples(st.just("tick"), st.integers(1, 30)),
    st.tuples(st.just("crash"), st.integers(0, 4)),
    st.tuples(st.just("restart"), st.integers(0, 4)),
    st.tuples(st.just("partition"), st.integers(0, 4)),
    st.tuples(st.just("heal"), st.integers(0, 1)),
)


@settings(max_examples=12, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(ops=st.lists(OP, min_size=5, max_size=40),
       seed=st.integers(0, 2 ** 16))
def test_safety_original(ops, seed):
    run_schedule("original", ops, seed)


@settings(max_examples=12, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(ops=st.lists(OP, min_size=5, max_size=40),
       seed=st.integers(0, 2 ** 16))
def test_safety_nezha_with_gc(ops, seed):
    run_schedule("nezha", ops, seed)


def test_leader_emerges_and_commits():
    wd = tempfile.mkdtemp()
    c = Cluster(n=5, engine="original", workdir=wd, seed=3)
    ld = c.elect()
    assert ld.role == LEADER
    c.put(b"a", b"1")
    assert c.get(b"a") == b"1"
    c.destroy()


def test_single_node_cluster_commits():
    """A peerless leader must self-commit (no AppendEntriesReply ever
    arrives to drive _advance_commit)."""
    wd = tempfile.mkdtemp()
    c = Cluster(n=1, engine="nezha", workdir=wd, seed=3,
                engine_kwargs={"gc_threshold": 1 << 60})
    c.put(b"solo", b"1")
    assert c.get(b"solo") == b"1"
    assert c.put_many([(f"s{i}".encode(), b"v") for i in range(10)],
                      batch=4) == 10
    assert c.get(b"s7") == b"v"
    c.destroy()


def test_batched_converges_to_same_state_as_unbatched():
    """Seeded A/B run: put_many with batch=1 vs batch=16 must produce the
    same applied state on every node (group commit changes fsync counts,
    never semantics)."""
    items = [(f"k{i:05d}".encode(), bytes([i % 256]) * 48)
             for i in range(120)]
    scans = {}
    applied = {}
    for batch in (1, 16):
        wd = tempfile.mkdtemp(prefix=f"ab_b{batch}_")
        c = Cluster(n=3, engine="nezha", workdir=wd, seed=21,
                    max_batch=batch,
                    engine_kwargs={"gc_threshold": 48 << 10})
        c.put_many(items, window=32, batch=batch)
        c.tick(200)   # let followers catch up + apply
        check_safety(c)
        scans[batch] = c.scan(b"", b"\xff" * 8)
        ld = c.elect()
        applied[batch] = [(i, e.key, e.value) for i, e in ld.applied_log
                         if e.key]
        c.destroy()
    assert scans[1] == scans[16]
    assert applied[1] == applied[16]


def test_leader_crash_mid_batch_never_commits_torn_prefix():
    """A leader that crashes right after group-committing a batch locally
    (before replicating it) must never surface any suffix of that batch as
    committed: the new leader's log wins, and after the old leader restarts
    all nodes agree (no torn prefix in any applied sequence)."""
    wd = tempfile.mkdtemp(prefix="torn_")
    c = Cluster(n=3, engine="original", workdir=wd, seed=9, max_batch=8)
    ld = c.elect()
    c.put(b"base", b"0")
    # isolate the leader so the batch is group-committed locally (one
    # buffered write + fsync) but its eager broadcast never arrives
    for i in range(3):
        if i != ld.nid:
            c.net.partition(ld.nid, i)
    batch = [(f"torn{i:02d}".encode(), bytes([i]) * 32) for i in range(8)]
    idxs = ld.client_put_many(batch)
    assert idxs is not None and len(idxs) == 8
    commit_before = ld.commit_index
    c.crash(ld.nid)          # batch persisted locally, never replicated
    c.net.heal()
    assert commit_before < idxs[0], "batch must not be committed yet"
    c.tick(600)              # new leader among the survivors
    new_ld = c.elect()
    assert new_ld.nid != ld.nid
    # survivors never saw the batch: none of it may be applied
    for nd in c.nodes:
        if nd is None:
            continue
        assert all(not e.key.startswith(b"torn") for _, e in nd.applied_log)
    c.put(b"after", b"1")    # cluster is live and commits fresh entries
    c.restart(ld.nid)        # old leader returns with the orphaned batch
    c.tick(600)
    check_safety(c)          # its log was truncated to match the new leader
    assert c.get(b"after") == b"1"
    assert c.get(b"base") == b"0"
    assert c.get(b"torn00") is None
    c.destroy()


def test_minority_partition_cannot_commit():
    wd = tempfile.mkdtemp()
    c = Cluster(n=3, engine="original", workdir=wd, seed=5)
    ld = c.elect()
    # cut the leader off from both followers
    for i in range(3):
        if i != ld.nid:
            c.net.partition(ld.nid, i)
    idx = ld.client_put(b"x", b"y")
    c.tick(150)
    assert ld.last_applied < idx, "entry committed without a majority"
    c.net.heal()
    c.tick(400)
    # after healing, some leader exists and the cluster can commit again
    c.put(b"z", b"w")
    assert c.get(b"z") == b"w"
    c.destroy()
