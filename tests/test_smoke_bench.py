"""`pytest -m smoke` target wrapping benchmarks/run.py --smoke: every engine
sustains puts through the batched pipeline, nezha beats original on value
write bytes, and group commit cuts fsyncs."""
import pytest


@pytest.mark.smoke
def test_smoke_benchmark_gate():
    from benchmarks.run import smoke
    assert smoke() == 0
