"""Sharding rules + HLO analyzer unit tests, and an end-to-end multi-device
train step run in a subprocess (device count must be set before jax init)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloModule, analyze, type_bytes
from repro.sharding.rules import fit_spec, make_rules, param_spec
from jax.sharding import PartitionSpec as P


def test_type_bytes():
    assert type_bytes("bf16[128,128]{1,0}") == 128 * 128 * 2
    assert type_bytes("(s32[], f32[4,2]{1,0})") == 4 + 32
    assert type_bytes("pred[]") == 1
    # replica_groups must NOT parse as a shape
    assert type_bytes("replica_groups=[32,16]<=[512]") == 0


def test_analyzer_counts_loop_trips_exactly():
    def f(w, x):
        def body(c, wi):
            return c @ wi, ()
        y, _ = jax.lax.scan(body, x, w)
        return y

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cost = analyze(c.as_text())
    assert cost.flops == 8 * 2 * 128 ** 3


def test_analyzer_nested_scans():
    def f(w, x):
        def outer(c, wi):
            def inner(c2, _):
                return c2 @ wi, ()
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, w)
        return y

    w = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cost = analyze(c.as_text())
    assert cost.flops == 3 * 4 * 2 * 64 ** 3


def _mesh():
    from repro.launch.mesh import mesh_axis_kwargs
    return jax.make_mesh((1, 1), ("data", "model"), **mesh_axis_kwargs(2))


def test_fit_spec_drops_indivisible_axes():
    from repro.launch.mesh import mesh_axis_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_axis_kwargs(1))
    assert fit_spec((7,), P("data"), mesh) == P("data")  # 7 % 1 == 0
    # batch=1 cannot shard over a >1 axis — simulated via spec entries
    rules = make_rules(_mesh())
    s = rules.sharding((1, 1), "batch")
    assert s.spec == P(None, None) or s.spec == P("data", None)


def test_param_spec_routing():
    rules = make_rules(_mesh())
    assert param_spec("layers/0/attn/wq", (4, 64, 64), rules)[0] is None
    assert param_spec("embed/embedding", (128, 64), rules) is not None
    # biases/scales stay replicated
    sp = param_spec("layers/0/attn/wq_bias", (4, 64), rules)
    assert all(a is None for a in tuple(sp))


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"  # 8 host devices, never real TPU
    import jax, jax.numpy as jnp, json
    from repro.configs import get, ShapeConfig
    from repro.launch.mesh import mesh_axis_kwargs
    from repro.launch.steps import make_train_step, make_init_fn, input_specs
    mesh = jax.make_mesh((4, 2), ("data", "model"), **mesh_axis_kwargs(2))
    out = {}
    for arch in ["smollm_135m", "olmoe_1b_7b", "zamba2_1p2b"]:
        cfg = get(arch, smoke=True)
        shape = ShapeConfig("s", seq_len=32, global_batch=8, kind="train")
        init_fn, _ = make_init_fn(cfg, mesh)
        state = init_fn(jax.random.PRNGKey(0))
        step, rules, _, b_sh = make_train_step(cfg, mesh, shape)
        ins = input_specs(cfg, shape)
        key = jax.random.PRNGKey(1)
        batch = {}
        for k, v in ins.items():
            if v.dtype == jnp.int32:
                batch[k] = jax.device_put(
                    jax.random.randint(key, v.shape, 0, cfg.vocab_size),
                    b_sh[k])
            else:
                batch[k] = jax.device_put(
                    jax.random.normal(key, v.shape, v.dtype), b_sh[k])
        l0 = None
        for _ in range(3):
            state, metrics = step(state, batch)
            l0 = l0 or float(metrics["loss"])
        out[arch] = [l0, float(metrics["loss"])]
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multi_device_train_step_subprocess():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT "):])
    for arch, (first, last) in res.items():
        assert last < first, f"{arch}: loss did not descend {first}->{last}"
