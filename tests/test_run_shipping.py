"""Run-shipping replication: leader-driven GC with follower run adoption.

Load-bearing claims under test:

  * leader-only GC: with run_shipping on, follower gc_sorted /
    gc_level_merge rewrite bytes stay 0 — sealed runs arrive as adoption
    records instead
  * equivalence: a follower-adopted store is byte-for-byte scan-equivalent
    to a locally-GC'd follower AND to the leader, including across crash,
    restart, and partition-during-ship schedules
  * ordering: adoption never races ahead of the applied log; a snapshot
    that lags the follower's applied state keeps the raft tail (no state
    regression)
  * resumability: chunks lost to crashes / partitions / lossy links are
    retransmitted (SimNet.dropped_msgs is the sender-visible signal);
    fence mismatches fall back to InstallSnapshot without divergence
"""
import os
import tempfile

from repro.core.cluster import Cluster
from repro.core.engines import NezhaEngine, _ShippedLSM
from repro.core.metrics import Metrics
from repro.core.simnet import SimNet
from repro.core.valuelog import KIND_PUT, LogEntry

HI = b"\xff" * 9


def make_ship_cluster(seed=5, drop_prob=0.0, n_nodes=3, **ekw):
    kw = {"gc_threshold": 16 << 10, "gc_batch": 64, "level_fanout": 2,
          "run_shipping": True}
    kw.update(ekw)
    wd = tempfile.mkdtemp(prefix="runship_")
    return Cluster(n=n_nodes, engine="nezha", workdir=wd, seed=seed,
                   drop_prob=drop_prob, engine_kwargs=kw)


def load(c, n, start=0, vsize=400):
    items = [(f"user{i:06d}".encode(), bytes([(i * 7) % 256]) * vsize)
             for i in range(start, start + n)]
    c.put_many(items, window=32)
    return dict(items)


def settle(c):
    ld = c.elect()
    c.engines[ld.nid].run_gc_to_completion()
    assert c.drain_shipping(max_ticks=12000)
    return c.elect()


def put(eng, key, value, term=1, apply=True):
    idx = getattr(eng, "_t_index", 0) + 1
    eng._t_index = idx
    e = LogEntry(term, idx, KIND_PUT, key, value)
    off = eng.append(e)
    if apply:
        eng.apply(e, off)
    return idx


def flush_active(eng, step=256):
    eng.start_gc()
    while not eng.gc_completed:
        eng.gc_step(step)


# --------------------------------------------------------- SimNet satellite
def test_simnet_drops_are_sender_visible():
    """Every discarded message — refused at send (down / partitioned /
    lossy) or destroyed in-flight by a crash — bumps dropped_msgs."""
    net = SimNet([0, 1, 2], seed=1)
    net.send(0, 1, "a")
    net.send(0, 1, "b")
    assert net.dropped_msgs == 0
    net.crash(1)                      # two messages were still in flight
    assert net.dropped_msgs == 2
    net.send(0, 1, "c")               # refused: receiver is down
    assert net.dropped_msgs == 3
    net.restart(1)
    net.partition(0, 1)
    net.send(0, 1, "d")               # refused: link blocked
    assert net.dropped_msgs == 4
    net.heal()
    lossy = SimNet([0, 1], seed=2, drop_prob=1.0)
    lossy.send(0, 1, "e")             # refused: lossy link
    assert lossy.dropped_msgs == 1


# ------------------------------------------------------- on_ship satellite
def test_on_ship_channel_unifies_replication_tags():
    """snapshot shipping, SST shipping and run shipping all account their
    wire bytes through Metrics.on_ship — one sum per node."""
    wd = tempfile.mkdtemp()
    m = Metrics()
    eng = NezhaEngine(wd, m, gc_threshold=1 << 60)
    for i in range(60):
        put(eng, f"key{i:04d}".encode(), bytes([i]) * 64)
    flush_active(eng)
    payload = eng.leveled.snapshot_payload()
    assert m.ship_bytes["snapshot"] == sum(len(p["data"]) for p in payload)
    assert "snapshot_ship" not in m.read_bytes    # old ad-hoc tag retired
    eng.close()

    wd2 = tempfile.mkdtemp()
    m2 = Metrics()
    db = _ShippedLSM(wd2, m2, wal=False)
    for i in range(50):
        db.put(f"k{i:03d}".encode(), b"v" * 32)
    db.flush()
    db.compact()
    assert m2.ship_bytes["sst"] > 0
    assert m2.ship_bytes["sst"] == m2.write_bytes["sst_ship"]
    assert m2.total_ship_bytes() == sum(m2.ship_bytes.values())
    db.destroy()


# ------------------------------------------------------------ the tentpole
def test_leader_only_gc_followers_adopt():
    """Followers re-run zero GC: their rewrite counters stay 0 while their
    run hierarchy and scans converge to the leader's exactly."""
    c = make_ship_cluster(seed=5)
    model = load(c, 200, vsize=512)
    ld = settle(c)
    le = c.engines[ld.nid]
    assert le.gc_count >= 2
    assert c.metrics[ld.nid].ship_bytes["run"] > 0
    lscan = le.scan(b"", HI)
    assert dict(lscan) == model
    for f in range(c.n):
        if f == ld.nid:
            continue
        m, fe = c.metrics[f], c.engines[f]
        assert m.write_bytes.get("gc_sorted", 0) == 0
        assert m.write_bytes.get("gc_level_merge", 0) == 0
        assert [(r.level, r.last_index) for r in fe.leveled.runs] == \
            [(r.level, r.last_index) for r in le.leveled.runs]
        assert fe.scan(b"", HI) == lscan
    rep = c.replication_report()
    assert all(r["gc_flush_bytes"] == 0 for r in rep
               if r["role"] == "follower")
    c.destroy()


def test_adopted_follower_matches_local_gc_follower():
    """A/B: same workload with run shipping on vs off — the adopted
    follower store is byte-for-byte scan-equivalent to the locally-GC'd
    one (and both match their leaders)."""
    scans = {}
    for mode in (True, False):
        c = make_ship_cluster(seed=13, run_shipping=mode)
        load(c, 180, vsize=512)
        ld = settle(c) if mode else c.elect()
        if not mode:
            c.engines[ld.nid].run_gc_to_completion()
            for _ in range(400):
                c.tick()
                if all(c.nodes[p].last_applied >= ld.commit_index
                       for p in ld.peers):
                    break
        le = c.engines[ld.nid]
        fol = [c.engines[f].scan(b"", HI) for f in range(c.n)
               if f != ld.nid]
        assert all(s == le.scan(b"", HI) for s in fol)
        scans[mode] = le.scan(b"", HI)
        if mode:    # the local-GC baseline actually did follower GC
            assert all(c.metrics[f].write_bytes.get("gc_sorted", 0) == 0
                       for f in range(c.n) if f != ld.nid)
        c.destroy()
    assert scans[True] == scans[False]


def test_follower_tail_survives_adoption_and_restart():
    """Entries past the adopted boundary (the rewritten raft tail) stay
    readable, truncatable and durable across a follower restart."""
    c = make_ship_cluster(seed=7)
    load(c, 150, vsize=512)
    ld = settle(c)
    # at least one follower took the adoption path (the other may have
    # been caught up by a log-compaction snapshot): test the adopter
    fid = max((i for i in range(c.n) if i != ld.nid),
              key=lambda i: c.engines[i].adopt_count)
    fe = c.engines[fid]
    assert fe.adopt_count >= 1
    # the tail segment holds only post-boundary entries
    boundary = fe.leveled.boundary[0]
    assert all(i > boundary for i in fe._seg_of_index)
    model = load(c, 30, start=150)       # post-adoption traffic
    for _ in range(200):
        c.tick()
        if c.nodes[fid].last_applied >= c.elect().commit_index:
            break
    c.crash(fid)
    c.restart(fid)
    for _ in range(400):
        c.tick()
        if c.nodes[fid].last_applied >= c.elect().commit_index:
            break
    ld = c.elect()
    assert c.engines[fid].scan(b"", HI) == c.engines[ld.nid].scan(b"", HI)
    for k, v in list(model.items())[:5]:
        assert c.engines[fid].get(k) == v
    c.destroy()


# ------------------------------------------------- fault schedules / resume
def test_partition_during_ship_resumes_chunks():
    """Chunks dropped while a follower is partitioned (sender-visible via
    dropped_msgs) are retransmitted after heal and the SAME record is
    adopted — no snapshot needed for a log-complete follower."""
    c = make_ship_cluster(seed=9, gc_threshold=1 << 60)
    load(c, 120, vsize=512)
    ld = c.elect()
    fid = [i for i in range(c.n) if i != ld.nid][0]
    # everyone is log-complete; now cut one follower off and seal a run
    for _ in range(100):
        c.tick()
        if all(c.nodes[p].last_applied >= ld.commit_index
               for p in ld.peers):
            break
    c.net.partition(ld.nid, fid)
    le = c.engines[ld.nid]
    le.start_gc()
    le.run_gc_to_completion()
    dropped0 = c.net.dropped_msgs
    adopted0 = c.engines[fid].adopt_count
    snap0 = c.metrics[ld.nid].ship_bytes.get("snapshot", 0)
    # short window: at least one chunk volley is dropped, but the follower
    # does not reach its election timeout (leadership stays put)
    for _ in range(14):
        c.tick()      # ship attempts at the partitioned peer are dropped
    assert c.net.dropped_msgs > dropped0
    assert c.engines[fid].adopt_count == adopted0
    c.net.heal()
    assert c.drain_shipping(max_ticks=6000)
    assert c.engines[fid].adopt_count > adopted0     # chunk resume, not
    assert c.metrics[ld.nid].ship_bytes.get("snapshot", 0) == snap0  # snap
    ld = c.elect()
    assert c.engines[fid].scan(b"", HI) == c.engines[ld.nid].scan(b"", HI)
    c.destroy()


def test_crash_restart_during_ship_converges():
    """Crash a follower while records are in flight (in-flight chunks are
    destroyed — dropped_msgs says so), write more through two further GC
    cycles, restart: the follower converges with zero local GC."""
    c = make_ship_cluster(seed=11)
    load(c, 120, vsize=512)
    ld = c.elect()
    fid = [i for i in range(c.n) if i != ld.nid][0]
    dropped0 = c.net.dropped_msgs
    c.crash(fid)
    assert c.net.dropped_msgs >= dropped0
    load(c, 120, start=120, vsize=512)   # leader keeps GC-ing + shipping
    c.restart(fid)
    ld = settle(c)
    fe = c.engines[fid]
    assert c.metrics[fid].write_bytes.get("gc_sorted", 0) == 0
    assert fe.scan(b"", HI) == c.engines[ld.nid].scan(b"", HI)
    assert [r.last_index for r in fe.leveled.runs] == \
        [r.last_index for r in c.engines[ld.nid].leveled.runs]
    c.destroy()


def test_chaos_lossy_network_linearizable_and_convergent():
    """Satellite: seeded drop_prob chaos over put/GC/ship traffic — reads
    of every committed key are the latest committed value, and every
    node's run SET (not just scan contents) eventually converges."""
    for seed, dp in ((3, 0.05), (21, 0.1)):
        c = make_ship_cluster(seed=seed, drop_prob=dp)
        model = load(c, 150)
        model.update(load(c, 50, start=100))    # overwrites: latest wins
        ld = settle(c)
        le = c.engines[ld.nid]
        assert all(le.get(k) == v for k, v in model.items())
        assert dict(le.scan(b"", HI)) == model
        runsets = {tuple((r.level, r.last_index) for r in e.leveled.runs)
                   for e in c.engines}
        assert len(runsets) == 1, runsets
        lscan = le.scan(b"", HI)
        assert all(c.engines[f].scan(b"", HI) == lscan
                   for f in range(c.n) if f != ld.nid)
        assert c.net.dropped_msgs > 0    # the schedule actually lost mail
        assert all(c.metrics[f].write_bytes.get("gc_sorted", 0) == 0
                   for f in range(c.n) if f != ld.nid)
        c.destroy()


def test_leader_crash_failover_ships_from_new_lineage():
    """Kill the leader mid-shipping: a follower that got its state via
    adoption takes over, runs GC itself, and ships from its own lineage;
    the deposed leader returns, is fenced/resynced, and converges."""
    c = make_ship_cluster(seed=9)
    model = dict(load(c, 150))
    old = c.elect()
    c.crash(old.nid)
    model.update(load(c, 100, start=100, vsize=444))   # overwrites
    c.restart(old.nid)
    ld = settle(c)
    le = c.engines[ld.nid]
    assert ld.nid != old.nid
    assert all(le.get(k) == v for k, v in model.items())
    lscan = le.scan(b"", HI)
    assert all(c.engines[f].scan(b"", HI) == lscan
               for f in range(c.n) if f != ld.nid)
    # the always-follower node never rewrote a byte of GC work
    bystander = [i for i in range(c.n) if i not in (ld.nid, old.nid)][0]
    assert c.metrics[bystander].write_bytes.get("gc_sorted", 0) == 0
    c.destroy()


# ------------------------------------------------------- fencing / fallback
def test_adopt_fences_reject_divergence_and_staleness():
    """Engine-level: adoption refuses stale records, mismatched manifests
    and concurrent local GC — the RunAdopter then requests a resync."""
    src = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                      run_shipping=True)
    records = []
    src.ship_hook = lambda rec, data: records.append((rec, data))
    src.raft_role = lambda: True
    for i in range(80):
        put(src, f"key{i:04d}".encode(), bytes([i]) * 64)
    flush_active(src)
    rec, data = records[0]
    rec = dict(rec, pos=(1, 1))

    fol = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                      run_shipping=True)
    for i in range(80):
        put(fol, f"key{i:04d}".encode(), bytes([i]) * 64)
    # diverged follower: it ran local GC, boundary no longer (0, 0)
    flush_active(fol)
    ok, _ = fol.adopt_run(rec, data)
    assert not ok
    fol.close()

    fol2 = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                       run_shipping=True)
    for i in range(80):
        put(fol2, f"key{i:04d}".encode(), bytes([i]) * 64)
    ok, _ = fol2.adopt_run(dict(rec, runs_before=3), data)
    assert not ok      # structural gap: records were missed in between
    ok, offsets = fol2.adopt_run(rec, data)      # clean adoption
    assert ok and offsets == {}                  # no tail past boundary
    assert fol2.leveled.ship_pos == (1, 1)
    ok, _ = fol2.adopt_run(rec, data)            # duplicate: fenced
    assert not ok
    assert dict(fol2.scan(b"", HI)) == dict(src.scan(b"", HI))
    fol2.close()
    src.close()


def test_adopt_flush_and_merge_records_engine_level():
    """Direct record replay: flushes then a merge (with retire list) give
    the follower the leader's exact hierarchy, and the follower's raft
    tail past the boundary is rewritten, applied and truncatable."""
    src = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                      level_fanout=2, run_shipping=True)
    records = []
    src.ship_hook = lambda rec, data: records.append(
        (dict(rec, pos=(1, len(records) + 1)), data))
    src.raft_role = lambda: True
    model = {}
    for r in range(2):
        for i in range(40):
            k = f"key{(r * 40 + i):04d}".encode()
            v = bytes([(r * 40 + i) % 256]) * 64
            put(src, k, v)
            model[k] = v
        flush_active(src)
    src.run_gc_to_completion()          # fanout=2 -> one merge record
    kinds = [rec["kind"] for rec, _ in records]
    assert kinds == ["flush", "flush", "merge"]
    assert records[2][0]["retire"], "merge record must retire its inputs"

    fol = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                      level_fanout=2, run_shipping=True)
    for k, v in model.items():
        put(fol, k, v)
    put(fol, b"tail-key", b"tail-value")          # past every boundary
    for rec, data in records:
        ok, offsets = fol.adopt_run(rec, data)
        assert ok, rec
    assert [(r.level, r.last_index) for r in fol.leveled.runs] == \
        [(r.level, r.last_index) for r in src.leveled.runs]
    # the manifest epoch advances in lock-step on the pure adoption path:
    # leader seals and follower adoptions are the same mutation count
    assert fol.leveled.epoch == src.leveled.epoch
    assert fol.get(b"tail-key") == b"tail-value"  # rewritten tail applied
    expect = dict(model)
    expect[b"tail-key"] = b"tail-value"
    assert dict(fol.scan(b"", HI)) == expect
    assert c_metrics_gc(fol) == 0
    fol.close()
    src.close()


def c_metrics_gc(eng):
    return eng.metrics.write_bytes.get("gc_sorted", 0) + \
        eng.metrics.write_bytes.get("gc_level_merge", 0)


def test_adoption_survives_crash_between_manifest_and_rotation():
    """Crash after the run-adoption manifest commit but before the active
    rotation commit: recovery serves every key (run + old segment overlap
    is read-tolerated) and the next adoption still lands."""
    src = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60,
                      run_shipping=True)
    records = []
    src.ship_hook = lambda rec, data: records.append(
        (dict(rec, pos=(1, len(records) + 1)), data))
    src.raft_role = lambda: True
    model = {}
    for r in range(2):
        for i in range(40):
            k = f"key{(r * 40 + i):04d}".encode()
            v = bytes([(r * 40 + i) % 256]) * 64
            put(src, k, v)
            model[k] = v
        flush_active(src)

    wd = tempfile.mkdtemp()
    fol = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60,
                      run_shipping=True)
    for k, v in model.items():
        put(fol, k, v)
    orig = NezhaEngine._retire_active_prefix

    def crash_before_rotation(self, li, lt):
        raise RuntimeError("simulated crash")

    NezhaEngine._retire_active_prefix = crash_before_rotation
    try:
        try:
            fol.adopt_run(*records[0])
        except RuntimeError:
            pass
    finally:
        NezhaEngine._retire_active_prefix = orig
    fol.close()

    fol2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60,
                       run_shipping=True)
    entries, offsets, si, _ = fol2.recover()
    assert si == records[0][0]["last_index"]     # manifest committed
    for e, off in zip(entries, offsets):
        fol2.apply(e, off)
    assert dict(fol2.scan(b"", HI)) == model     # overlap tolerated
    ok, _ = fol2.adopt_run(*records[1])          # next record still lands
    assert ok
    assert dict(fol2.scan(b"", HI)) == model
    fol2.close()
    src.close()


def test_install_crash_between_manifest_swap_and_rotation_repairs():
    """Crash after InstallSnapshot's manifest swap but before the segment
    rotation commit: recovery must rebuild the active segment tail-only,
    or its stale applied records would shadow the newer run data the
    snapshot carried."""
    import pytest
    from repro.core.storage import LeveledStore
    src = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60)
    for i in range(30):
        put(src, f"key{i:04d}".encode(), b"OLD " + bytes([i]) * 32)
    for i in range(30):                      # overwrites: indices 31..60
        put(src, f"key{i:04d}".encode(), b"NEW " + bytes([i]) * 32)
    flush_active(src)
    li, lt, payload = src.snapshot()

    wd = tempfile.mkdtemp()
    fol = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    for i in range(30):                      # applied only the OLD prefix
        put(fol, f"key{i:04d}".encode(), b"OLD " + bytes([i]) * 32)
    orig = LeveledStore.install_payload

    def crash_after_swap(self, *a, **k):
        orig(self, *a, **k)
        raise RuntimeError("simulated crash")

    fol.leveled.install_payload = crash_after_swap.__get__(fol.leveled)
    with pytest.raises(RuntimeError):
        fol.install_snapshot(li, lt, payload, keep_tail=False)
    fol.close()

    fol2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    _, _, si, _ = fol2.recover()
    assert si == li
    assert fol2.get(b"key0005") == b"NEW " + bytes([5]) * 32   # not OLD
    assert dict(fol2.scan(b"", HI)) == dict(src.scan(b"", HI))
    assert fol2._seg_of_index == {}          # tail-only rebuild
    fol2.close()
    src.close()


def test_torn_write_in_gc_commit_window_recovers():
    """FaultFS satellite: kill -9 with a torn tail at several offsets
    inside the leader's GC commit window — run build+sync, the
    runs_manifest.json replace, the gc_state.json commit, the stale-file
    deletes.  Every acked write was load()ed before the fault, so NONE
    may be lost; the manifest must parse to either the old or the new
    run set (write_json_atomic), and the cluster reconverges byte-equal
    after restart with a clean structural audit."""
    from repro.core import faultfs
    from repro.core.faultfs import FaultFS, SimulatedCrash
    from repro.core.workload import _audit_cluster

    # (scope-suffix, op offset): run files + manifest early/mid, and the
    # gc_state.json commit point itself
    probes = [("run", 0), ("run", 2), ("run", 4), ("gc_state.json", 0)]
    for suffix, k in probes:
        fs = faultfs.install(FaultFS(seed=29 + k))
        try:
            # sync=True: the acked-durability claim only holds when acks
            # wait for fsync (the default async config may lose the
            # unsynced tail by design)
            c = Cluster(n=3, engine="nezha", sync=True, seed=17,
                        workdir=tempfile.mkdtemp(prefix="runship_cp_"),
                        engine_kwargs={"gc_threshold": 16 << 10,
                                       "gc_batch": 64, "level_fanout": 2,
                                       "run_shipping": True})
            model = load(c, 140, vsize=400)
            ld = c.elect()
            # drain pending level merges, then top up until the active
            # segment holds fresh data: the first load auto-GC's at the
            # threshold, and force_gc with a merge pending (or an empty
            # active segment) never enters the flush window — run build,
            # gc_state.json commit, segment rotation — this probe targets
            c.force_gc()
            extra = 140
            while c.engines[c.elect().nid]._last_by_tag.get(
                    c.engines[c.elect().nid].active.tag) is None:
                model.update(load(c, 5, start=extra, vsize=400))
                extra += 5
            ld = c.elect()
            ldir = c._engine_dir(ld.nid)
            fs.arm(k, scope=os.path.join(ldir, suffix), mode="torn")
            try:
                c.force_gc()
                crashed = False
            except SimulatedCrash as e:
                # crash_hard: drop the node un-closed, rewrite its dir to
                # the durable view (torn tail applied deterministically)
                assert c.hard_crash_from(e) == ld.nid
                crashed = True
            fs.disarm()
            assert crashed, f"probe {suffix}+{k} never reached the window"
            assert fs.counters()["crashes"] == 1
            c.restart(ld.nid)
            ld = settle(c)
            le = c.engines[ld.nid]
            assert dict(le.scan(b"", HI)) == model, \
                f"acked write lost at {suffix}+{k}"
            lscan = le.scan(b"", HI)
            assert all(c.engines[f].scan(b"", HI) == lscan
                       for f in range(c.n) if f != ld.nid)
            assert _audit_cluster(c) == []
            c.destroy()
        finally:
            faultfs.uninstall()


def test_install_snapshot_retains_applied_tail():
    """The regression fence: a (resync) snapshot whose boundary lags the
    follower's applied state must keep the applied tail — state machine
    contents never move backwards."""
    src = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60)
    for i in range(60):
        put(src, f"key{i:04d}".encode(), bytes([i]) * 64)
    flush_active(src)            # boundary at index 60
    li, lt, payload = src.snapshot()

    fol = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60)
    for i in range(60):
        put(fol, f"key{i:04d}".encode(), bytes([i]) * 64)
    for i in range(60, 80):      # applied past the snapshot boundary
        put(fol, f"key{i:04d}".encode(), b"T" * 32)
    offsets = fol.install_snapshot(li, lt, payload)
    assert set(offsets) == set(range(61, 81))
    for i in range(60, 80):      # the applied tail survived the install
        assert fol.get(f"key{i:04d}".encode()) == b"T" * 32
    assert fol.get(b"key0010") == bytes([10]) * 64
    assert len(fol.scan(b"", HI)) == 80
    fol.close()

    # divergent lineage: raft's term check at the boundary failed, the
    # (necessarily unapplied) local suffix is discarded with the log —
    # keeping it would plant stale duplicate indices in the fresh vlog
    fol2 = NezhaEngine(tempfile.mkdtemp(), Metrics(), gc_threshold=1 << 60)
    for i in range(40):
        put(fol2, f"old{i:04d}".encode(), b"x" * 16, apply=False)
    offsets = fol2.install_snapshot(li, lt, payload, keep_tail=False)
    assert offsets == {}
    assert fol2._seg_of_index == {}
    assert dict(fol2.scan(b"", HI)) == dict(src.scan(b"", HI))
    fol2.close()
    src.close()
