"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracle in each kernel's ref.py, plus hypothesis property tests on
the paged/compaction invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from repro.testing.minihyp import (HealthCheck, given, settings,
                                       strategies as st)

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.kv_compaction.ops import compact_kv_pool
from repro.kernels.kv_compaction.ref import compact_kv_pool_ref
from repro.kernels.paged_attention.ops import paged_decode_attention

KEY = jax.random.PRNGKey(0)


def tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


FLASH_SWEEP = [
    # (B, nh, nkv, S, hd, dtype, bq, bk)
    (2, 4, 2, 256, 64, jnp.float32, 128, 128),
    (1, 8, 8, 512, 128, jnp.bfloat16, 256, 128),
    (2, 6, 2, 128, 64, jnp.bfloat16, 128, 128),
    (1, 2, 1, 384, 64, jnp.float32, 128, 128),
    (3, 4, 4, 128, 256, jnp.float32, 64, 64),
    (1, 9, 3, 256, 64, jnp.bfloat16, 128, 64),   # smollm-style 9/3 heads
]


@pytest.mark.parametrize("B,nh,nkv,S,hd,dt,bq,bk", FLASH_SWEEP)
def test_flash_attention_sweep(B, nh, nkv, S, hd, dt, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, nh, S, hd), dt)
    k = jax.random.normal(ks[1], (B, nkv, S, hd), dt)
    v = jax.random.normal(ks[2], (B, nkv, S, hd), dt)
    ref = flash_attention(q, k, v, backend="reference")
    out = flash_attention(q, k, v, backend="pallas_interpret",
                          block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dt))


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 4, 256, 64), jnp.float32)
    ref = flash_attention(q, k, v, causal=False, backend="reference")
    out = flash_attention(q, k, v, causal=False,
                          backend="pallas_interpret", block_q=128,
                          block_k=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


PAGED_SWEEP = [
    # (B, nh, nkv, nblk, bs, hd, dtype)
    (2, 8, 2, 8, 16, 64, jnp.float32),
    (3, 4, 4, 4, 32, 128, jnp.bfloat16),
    (1, 16, 8, 16, 8, 64, jnp.bfloat16),
    (4, 2, 2, 2, 64, 128, jnp.float32),
]


@pytest.mark.parametrize("B,nh,nkv,nblk,bs,hd,dt", PAGED_SWEEP)
def test_paged_attention_sweep(B, nh, nkv, nblk, bs, hd, dt):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, nh, hd), dt)
    pk = jax.random.normal(ks[1], (B, nblk, bs, nkv, hd), dt)
    pv = jax.random.normal(ks[2], (B, nblk, bs, nkv, hd), dt)
    table = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[3], b), nblk)
        for b in range(B)]).astype(jnp.int32)
    length = jnp.array([max(1, nblk * bs - 5)] + [nblk * bs] * (B - 1),
                       jnp.int32)
    ref = paged_decode_attention(q, pk, pv, table, length,
                                 backend="reference")
    out = paged_decode_attention(q, pk, pv, table, length,
                                 backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dt))


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(B=st.integers(1, 3), nblk=st.integers(1, 8),
       bs=st.sampled_from([8, 16]), C=st.sampled_from([32, 64]),
       seed=st.integers(0, 1000))
def test_compaction_is_permutation_inverse(B, nblk, bs, C, seed):
    """Property: compaction output at logical block i == input at table[i];
    compacting an identity table is a no-op."""
    k = jax.random.PRNGKey(seed)
    pool = jax.random.normal(k, (B, nblk, bs, C), jnp.float32)
    table = jnp.stack([
        jax.random.permutation(jax.random.fold_in(k, b), nblk)
        for b in range(B)]).astype(jnp.int32)
    out, ident = compact_kv_pool(pool, table, backend="pallas_interpret")
    ref = compact_kv_pool_ref(pool, table)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    out2, _ = compact_kv_pool(out, ident, backend="pallas_interpret")
    assert np.array_equal(np.asarray(out2), np.asarray(out))


def test_paged_attention_invariant_under_compaction():
    """Attention(q, pool, table) == Attention(q, compact(pool), identity) —
    the kernel-level statement of the paper's GC correctness."""
    ks = jax.random.split(KEY, 4)
    B, nh, nkv, nblk, bs, hd = 2, 4, 2, 8, 16, 64
    q = jax.random.normal(ks[0], (B, nh, hd), jnp.float32)
    pk = jax.random.normal(ks[1], (B, nblk, bs, nkv, hd), jnp.float32)
    pv = jax.random.normal(ks[2], (B, nblk, bs, nkv, hd), jnp.float32)
    table = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[3], b), nblk)
        for b in range(B)]).astype(jnp.int32)
    length = jnp.full((B,), nblk * bs, jnp.int32)
    before = paged_decode_attention(q, pk, pv, table, length,
                                    backend="pallas_interpret")
    ck, ident = compact_kv_pool(pk.reshape(B, nblk, bs, -1), table,
                                backend="pallas_interpret")
    cv, _ = compact_kv_pool(pv.reshape(B, nblk, bs, -1), table,
                            backend="pallas_interpret")
    after = paged_decode_attention(
        q, ck.reshape(pk.shape), cv.reshape(pv.shape), ident, length,
        backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                               rtol=1e-6, atol=1e-6)
