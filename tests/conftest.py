import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real device; only launch/dryrun.py creates the 512 placeholders.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so `from benchmarks import ...` works regardless of invocation
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))
