"""Consistency-tiered client API (repro.core.client): the stale-read
regression the old direct-engine read path permitted, plus the guarantees
each tier claims — ReadIndex safety + batching, lease zero-round reads and
expiry, session read-your-writes / monotonic reads on followers.

The headline test proves WHY the API redesign exists: a deposed leader on
the minority side of a partition keeps role=LEADER (nothing ever tells it
otherwise) and its engine happily serves state the majority has already
overwritten.  The old `Cluster.get` read exactly that engine; the tiers
refuse or redirect instead.
"""
import tempfile

import pytest

from repro.core.client import (LEASE, LINEARIZABLE, SESSION, NezhaClient,
                               StaleReadError)
from repro.core.cluster import Cluster
from repro.core.raft import LEADER

HI = b"\xff" * 11


def make_cluster(n=3, seed=4, **engine_kw):
    wd = tempfile.mkdtemp(prefix="clientreads_")
    kw = {"gc_threshold": 1 << 60}
    kw.update(engine_kw)
    return Cluster(n=n, engine="nezha", workdir=wd, seed=seed,
                   engine_kwargs=kw)


def partition_leader_to_minority(c: Cluster, ld):
    for i in range(c.n):
        if i != ld.nid:
            c.net.partition(ld.nid, i)


def elect_new_majority_leader(c: Cluster, old):
    for _ in range(4000):
        c.tick()
        nl = c.leader()
        if nl is not None and nl.nid != old.nid and \
                nl.commit_index >= nl.snap_index:
            return nl
    raise TimeoutError("no majority leader emerged")


# ---------------------------------------------------------------- headline
def test_deposed_leader_stale_read_hole_closed_by_tiers():
    """Partition a leader into the minority, commit a newer value on the
    majority: the OLD direct-engine read still returns the stale value
    (the hole), while every tier pinned to the deposed leader refuses and
    unpinned reads redirect to fresh state."""
    c = make_cluster()
    ses = c.session()
    ses.put(b"k", b"old")
    ld = c.elect()
    partition_leader_to_minority(c, ld)
    elect_new_majority_leader(c, ld)
    ses.put(b"k", b"new")           # commits on the majority side

    # the deposed leader still believes it leads, and its engine is stale:
    # this is precisely the read the old Cluster.get used to serve
    assert ld.role == LEADER
    assert c.engines[ld.nid].get(b"k") == b"old"

    # LINEARIZABLE pinned: ReadIndex can't confirm leadership -> refused
    with pytest.raises(StaleReadError):
        c.get(b"k", LINEARIZABLE, node=ld.nid)
    # LEASE pinned: the lease lapsed long ago -> falls back to ReadIndex
    # on the same node -> refused
    assert not ld.lease_valid()
    with pytest.raises(StaleReadError):
        c.get(b"k", LEASE, node=ld.nid)
    # SESSION pinned: applied state lags the session token -> refused
    with pytest.raises(StaleReadError):
        c.get(b"k", SESSION, session=ses, node=ld.nid)

    # unpinned reads redirect to the majority and see the new value
    assert c.get(b"k") == b"new"
    assert c.get(b"k", LEASE) == b"new"
    assert ses.get(b"k") == b"new"
    c.destroy()


# ------------------------------------------------------------ linearizable
def test_linearizable_batch_costs_one_quorum_round():
    """N queued reads ride ONE heartbeat-quorum round (the ReadIndex
    batching the ISSUE asks for), vs one round per read when serial."""
    c = make_cluster()
    items = [(f"b{i:03d}".encode(), bytes([i]) * 32) for i in range(16)]
    c.put_many(items)

    rounds = lambda: sum(m.read_quorum_rounds for m in c.metrics)
    r0 = rounds()
    out = c.client.get_many([k for k, _ in items])
    assert out == [v for _, v in items]
    assert rounds() - r0 == 1

    r0 = rounds()
    for k, v in items[:8]:
        assert c.get(k) == v          # serial: one round each
    assert rounds() - r0 == 8
    c.destroy()


def test_linearizable_waits_for_apply_up_to_read_index():
    """A read submitted right after a write must observe it (the handle
    serves only once last_applied >= the recorded commit index)."""
    c = make_cluster()
    for i in range(5):
        c.put(f"w{i}".encode(), bytes([i]))
        assert c.get(f"w{i}".encode()) == bytes([i])
    c.destroy()


def test_single_node_cluster_all_tiers():
    c = make_cluster(n=1, seed=3)
    ses = c.session()
    ses.put(b"solo", b"1")
    assert c.get(b"solo") == b"1"
    assert c.get(b"solo", LEASE) == b"1"
    assert ses.get(b"solo") == b"1"
    c.destroy()


# ------------------------------------------------------------------- lease
def test_lease_reads_pay_zero_quorum_rounds_under_stable_leader():
    c = make_cluster()
    items = [(f"l{i:03d}".encode(), bytes([i]) * 16) for i in range(12)]
    c.put_many(items)
    ld = c.elect()
    assert ld.lease_valid()           # renewed by the put traffic
    rounds = lambda: sum(m.read_quorum_rounds for m in c.metrics)
    r0 = rounds()
    for k, v in items:
        assert c.get(k, LEASE) == v
    assert rounds() - r0 == 0
    assert c.metrics[ld.nid].read_tiers["lease"] >= len(items)
    c.destroy()


def test_lease_expires_without_heartbeat_acks():
    """Isolate the leader and let lease_ticks elapse: lease_valid() must
    flip false — the window in which a partitioned leader could lie is
    bounded below the minimum election timeout by construction."""
    c = make_cluster()
    c.put(b"k", b"v")
    ld = c.elect()
    assert ld.lease_valid()
    partition_leader_to_minority(c, ld)
    for _ in range(ld.lease_ticks + c.net.max_delay + 1):
        c.tick()
    assert ld.role == LEADER          # nobody told it otherwise...
    assert not ld.lease_valid()       # ...but it can no longer serve
    assert ld.lease_ticks < c.election_timeout[0]
    c.destroy()


def test_lease_quorum_follower_cannot_elect_rival_leader():
    """Leader stickiness (Raft §9.6) is the second leg of lease safety:
    partition the leader from ONE follower only.  The shared follower —
    whose probe acks keep renewing the lease — must disregard the
    partitioned node's vote requests while the leader is live, so no
    rival leader can form inside the lease window and a pinned LEASE
    read stays current (this exact config produced a stale read before
    the stickiness check existed)."""
    wd = tempfile.mkdtemp(prefix="sticky_")
    c = Cluster(n=3, engine="nezha", workdir=wd, seed=1,
                heartbeat_every=12, election_timeout=(40, 80),
                engine_kwargs={"gc_threshold": 1 << 60})
    c.put(b"k", b"v1")
    ld = c.elect()
    b = [i for i in range(3) if i != ld.nid][0]
    c.net.partition(ld.nid, b)
    for _ in range(1200):
        c.tick()
        nl = c.leader()
        assert nl is None or nl.nid == ld.nid, \
            "rival leader elected while the old lease could still be valid"
    assert c.get(b"k", LEASE, node=ld.nid) == b"v1"   # current, not stale
    c.net.heal()
    c.put(b"k", b"v2")                # liveness intact after the heal
    assert c.get(b"k", LEASE) == b"v2"
    c.destroy()


def test_restarted_hint_node_keeps_full_election_timeout():
    """The deterministic-first-leader nudge is construction-only: a
    RESTARTED leader_hint node must come back with the full election
    timeout, or it could stand for election inside the current leader's
    lease window."""
    c = make_cluster()
    hint = c.leader_hint
    ld = c.elect()
    assert ld.nid == hint             # the nudge did its one job
    c.crash(hint)
    c.elect()                         # another node takes over
    c.restart(hint)
    nd = c.nodes[hint]
    assert nd.election_deadline - c.net.time >= c.election_timeout[0], \
        "restart re-applied the halved first-election deadline"
    # and the restarted node is vote-sticky: before crashing it may have
    # renewed a lease that is still live, so it must disregard vote
    # requests for one minimum election timeout after coming back
    assert c.net.time - nd._last_leader_contact < c.election_timeout[0]
    c.destroy()


def test_oversized_lease_ticks_rejected_at_construction():
    """lease_ticks >= min election timeout would outlive the vote-
    stickiness window (the stale-lease hole): refused up front."""
    wd = tempfile.mkdtemp(prefix="badlease_")
    with pytest.raises(ValueError):
        Cluster(n=3, engine="nezha", workdir=wd, seed=0, lease_ticks=100,
                engine_kwargs={"gc_threshold": 1 << 60})


# ----------------------------------------------------------------- session
def test_session_read_your_writes_on_every_follower():
    c = make_cluster()
    ses = c.session()
    ses.put(b"ryw", b"mine")
    ld = c.elect()
    for f in range(c.n):
        if f != ld.nid:
            assert c.get(b"ryw", SESSION, session=ses, node=f) == b"mine"
    rep = c.read_report()
    assert sum(r["follower_serves"] for r in rep) == c.n - 1
    assert sum(r["tiers"].get("session", 0) for r in rep) == c.n - 1
    c.destroy()


def test_session_monotonic_read_stalls_on_lagging_follower():
    """A follower behind the session token must wait for its apply
    pipeline (counted as a session stall) instead of serving older
    state — monotonic reads."""
    c = make_cluster()
    ld = c.elect()
    lag = [i for i in range(3) if i != ld.nid][0]
    other = [i for i in range(3) if i not in (ld.nid, lag)][0]
    c.net.partition(ld.nid, lag)
    c.net.partition(other, lag)
    ses = c.session()
    ses.put(b"m", b"2")               # commits on the majority, lag is out
    assert c.nodes[lag].last_applied < ses.last_index
    c.net.heal()
    assert c.get(b"m", SESSION, session=ses, node=lag) == b"2"
    assert c.metrics[lag].session_stalls >= 1
    c.destroy()


def test_session_unpinned_redirects_around_lagging_node():
    """Unpinned session reads route around a node that cannot satisfy the
    token within the stall budget (partitioned forever here)."""
    c = make_cluster()
    ld = c.elect()
    lag = [i for i in range(3) if i != ld.nid][0]
    other = [i for i in range(3) if i not in (ld.nid, lag)][0]
    c.net.partition(ld.nid, lag)
    c.net.partition(other, lag)
    ses = c.session()
    ses.put(b"r", b"3")
    c.client.stall_ticks = 20         # don't burn the budget on the lagger
    for _ in range(4):                # round-robin passes over `lag` too
        assert ses.get(b"r") == b"3"
    c.destroy()


def test_session_scans_byte_equal_across_gc_and_shipping():
    """With run shipping (the default) followers adopt the leader's sealed
    runs, so a session scan served by a follower is byte-equal with the
    leader even after GC cycles rewrote the store."""
    c = make_cluster(gc_threshold=24 << 10, level_fanout=2)
    items = [(f"user{i:06d}".encode(), bytes([i % 256]) * 512)
             for i in range(200)]
    ses = c.session()
    ses.put_many(items)
    ld = c.elect()
    c.engines[ld.nid].run_gc_to_completion()
    assert c.engines[ld.nid].gc_count >= 1
    assert c.drain_shipping()
    lscan = c.engines[ld.nid].scan(b"", HI)
    assert lscan == sorted(items)
    for f in range(c.n):
        if f != ld.nid:
            assert c.scan(b"", HI, SESSION, session=ses, node=f) == lscan
            # and the follower really did zero GC rewrite work
            assert c.metrics[f].write_bytes.get("gc_sorted", 0) == 0
    c.destroy()


# ------------------------------------------------------------------ writes
def test_put_many_resubmits_after_leadership_change():
    """put_many must not count writes submitted to a deposed leader as
    committed: its indexes may name different entries in the new leader's
    log.  Partition the leader mid-stream: every unconfirmed chunk is
    resubmitted to the majority leader, the call returns the full count,
    and every item is durably readable — with the session token tracking
    the indexes actually applied (read-your-writes on followers)."""
    c = make_cluster()
    c.put(b"seed", b"s")
    ld = c.elect()
    partition_leader_to_minority(c, ld)
    ses = c.session()
    items = [(f"pm{i:03d}".encode(), bytes([i]) * 32) for i in range(24)]
    # submission starts on the old leader (still the only one known) and
    # must migrate to the majority leader elected mid-drain
    assert ses.put_many(items, window=8, batch=4) == 24
    nl = c.leader()
    assert nl is not None and nl.nid != ld.nid
    for k, v in items:
        assert c.get(k) == v          # linearizable: all 24 committed
    fol = [i for i in range(3) if i not in (ld.nid, nl.nid)][0]
    assert c.get(items[-1][0], SESSION, session=ses, node=fol) == \
        items[-1][1]
    c.destroy()



def test_put_survives_deposed_leader_via_loop_retry():
    """client.put retries through leadership changes with a LOOP (the old
    Cluster.put recursed): a put targeted at a leader that gets deposed
    mid-flight must still commit via the new majority leader."""
    c = make_cluster()
    c.put(b"x", b"1")
    ld = c.elect()
    partition_leader_to_minority(c, ld)
    # the client first submits to the stale leader (it is still the only
    # known one), then detects the higher-term leader and retries
    assert c.put(b"x", b"2") > 0
    assert c.get(b"x") == b"2"
    import inspect
    src = inspect.getsource(NezhaClient.put)
    assert "self.put(" not in src     # the retry really is a loop now
    c.destroy()


def test_default_read_is_linearizable_and_default_shipping_on():
    c = make_cluster()
    assert c.client.default_consistency == LINEARIZABLE
    for e in c.engines:
        assert e.run_shipping          # ROADMAP soak item: default on
    ld = c.elect()
    assert ld.shipper is not None      # cluster wired the shipper
    c.destroy()
