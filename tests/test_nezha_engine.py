"""Nezha storage-engine behaviour: the paper's mechanisms, byte-verified.

  * value-write counts: Original >= 3x vs Nezha == 1x (+ tiny index)
  * three-phase Get/Scan correctness while GC is in flight (Algorithms 2-3)
  * crash mid-GC -> resume from interrupt point (§III-E)
  * sorted store: scans are one seek + sequential bytes
"""
import json
import os
import tempfile

import pytest

from repro.core.engines import (ENGINES, NezhaEngine, NezhaNoGCEngine,
                                OriginalEngine)
from repro.core.metrics import Metrics
from repro.core.valuelog import KIND_PUT, LogEntry

VAL = 1024


def drive(eng, n, start=0, vsize=VAL, post_op=True):
    """Apply n puts directly (single-node state machine semantics)."""
    for i in range(start, start + n):
        e = LogEntry(1, i + 1, KIND_PUT, f"key{i:06d}".encode(),
                     bytes([i % 256]) * vsize)
        off = eng.append(e)
        eng.apply(e, off)
        if post_op:
            eng.post_op()
    return eng


def test_value_write_amplification_original_vs_nezha():
    results = {}
    for name in ["original", "nezha_nogc"]:
        wd = tempfile.mkdtemp()
        m = Metrics()
        kw = {"memtable": None}
        eng = ENGINES[name](wd, m)
        if isinstance(eng, OriginalEngine):
            eng.db.memtable_limit = 64 << 10   # force flush + compaction
            eng.db.l0_limit = 2
        drive(eng, 300)
        writes = dict(m.write_bytes)
        user = eng.user_bytes
        # bytes the VALUE itself hit disk (exclude 8B-offset index traffic)
        value_cats = {"raft_log", "wal", "flush", "compaction", "valuelog",
                      "wisckey_vlog"}
        value_bytes = sum(v for k, v in writes.items() if k in value_cats)
        results[name] = value_bytes / user
        eng.close()
    assert results["original"] >= 2.9, results    # >= 3x (paper's claim)
    assert results["nezha_nogc"] <= 1.2, results  # exactly once (+ framing)


def test_three_phase_reads_during_gc():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=96 << 10, gc_batch=4)
    drive(eng, 200, post_op=False)
    assert not eng.gc_started
    eng.post_op()  # below threshold? force check
    if not eng.gc_started:
        eng.start_gc()
    # During-GC: old data from Active, new writes to New, both visible
    assert eng.gc_started and not eng.gc_completed
    e = LogEntry(1, 999, KIND_PUT, b"key000010", b"NEW" * 100)
    off = eng.append(e)
    eng.apply(e, off)
    assert eng.get(b"key000010") == b"NEW" * 100       # newest wins
    assert eng.get(b"key000150") == bytes([150]) * VAL  # old still readable
    sc = dict(eng.scan(b"key000100", b"key000110"))
    assert len(sc) == 11
    # step GC to completion while interleaving reads
    while not eng.gc_completed:
        eng.gc_step(16)
        assert eng.get(b"key000010") == b"NEW" * 100
    # Post-GC: L0 run serves history, new module serves fresh data
    assert eng.leveled.runs
    assert eng.get(b"key000150") == bytes([150]) * VAL
    assert eng.get(b"key000010") == b"NEW" * 100
    eng.close()


def test_scan_is_one_seek_sequential_after_gc():
    wd = tempfile.mkdtemp()
    m = Metrics()
    eng = NezhaEngine(wd, m, gc_threshold=1 << 60)  # manual trigger
    drive(eng, 300, post_op=False)
    eng.start_gc()
    eng.run_gc_to_completion()
    m.read_ops.clear()
    m.read_bytes.clear()
    out = eng.scan(b"key000050", b"key000149")
    assert len(out) == 100
    # all bytes must come from ONE sorted_range read (plus index traffic 0)
    assert m.read_ops.get("sorted_range", 0) == 1, dict(m.read_ops)
    assert m.read_bytes["sorted_range"] >= 100 * VAL
    eng.close()


def test_crash_mid_gc_resumes_from_interrupt_point():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    drive(eng, 200, post_op=False)
    eng.start_gc()
    for _ in range(6):
        eng.gc_step(16)         # partial progress, then "crash"
    done_before = len(eng._building.keys)
    assert 0 < done_before < 200
    eng.close()

    eng2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    eng2.recover()
    assert eng2.gc_started and not eng2.gc_completed
    eng2.run_gc_to_completion()
    # nothing lost, nothing duplicated
    assert eng2.leveled.total_keys() == 200
    assert eng2.get(b"key000000") == bytes([0]) * VAL
    assert eng2.get(b"key000199") == bytes([199]) * VAL
    assert len(eng2.scan(b"key000000", b"key000199")) == 200
    eng2.close()


def test_recovery_replays_lightweight_offsets():
    """Fig 11 mechanism: Nezha's post-crash state machine rebuild reads only
    offsets + the sorted file, not 3x value bytes."""
    for name in ["original", "nezha"]:
        wd = tempfile.mkdtemp()
        m = Metrics()
        kw = dict(gc_threshold=128 << 10) if name == "nezha" else {}
        eng = ENGINES[name](wd, m, **kw)
        if name == "original":
            eng.db.memtable_limit = 64 << 10
        drive(eng, 300)
        if name == "nezha":
            eng.run_gc_to_completion()
        eng.close()
        m2 = Metrics()
        eng2 = ENGINES[name](wd, m2, **kw)
        eng2.recover()
        if name == "original":
            orig_recover = sum(m2.read_bytes.values())
        else:
            nezha_recover = sum(m2.read_bytes.values())
        eng2.close()
    # Nezha reads the sorted snapshot + small tail; Original re-scans the
    # full fat raft log (values) + WAL.  At minimum Nezha must not be worse.
    assert nezha_recover <= orig_recover * 1.1, (nezha_recover, orig_recover)


def test_snapshot_install_resets_follower_state():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    drive(eng, 100, post_op=False)
    eng.start_gc()
    eng.run_gc_to_completion()
    li, lt, payload = eng.snapshot()
    assert li == 100
    wd2 = tempfile.mkdtemp()
    fol = NezhaEngine(wd2, Metrics(), gc_threshold=1 << 60)
    drive(fol, 10, post_op=False)       # stale local state
    fol.install_snapshot(li, lt, payload)
    assert fol.get(b"key000099") == bytes([99]) * VAL
    assert len(fol.scan(b"key000000", b"key000099")) == 100
    fol.close()
    eng.close()
