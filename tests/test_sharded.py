"""Multi-Raft sharded keyspace (repro/core/shards.py).

Covers the PR's guarantees end to end: ShardMap routing, cross-shard
session guarantees (read-your-writes + monotonic reads with a put on
shard A and a get on shard B), scatter-gather scans byte-equal to an
unsharded reference store, chaos targeted at one group (other shards
keep serving; zero history violations), trace propagation (one put_many
root with per-shard subtrees, causality audit clean per group), and the
shard-labeled metrics registry / fabric health report.
"""
import pytest

from repro.core.client import LINEARIZABLE
from repro.core.cluster import Cluster
from repro.core.shards import ShardedCluster, ShardMap
from repro.core.trace import audit
from repro.core.workload import (ChaosSchedule, Tenant, WorkloadSpec,
                                 run_workload, _key)

pytestmark = pytest.mark.shard


def _keys(n, fmt=b"user%010d"):
    return [fmt % i for i in range(n)]


def _mk(tmp_path, keys, n_shards=4, n=3, seed=7, sub="sc", **kw):
    sc = ShardedCluster(n_shards=n_shards, n=n,
                        workdir=str(tmp_path / sub), seed=seed,
                        shard_map=ShardMap.from_keys(keys, n_shards), **kw)
    sc.elect()
    return sc


# ------------------------------------------------------------- shard map
def test_shardmap_routing_properties():
    keys = _keys(1000)
    sm = ShardMap.from_keys(keys, 4)
    assert sm.n_shards == 4
    # quantile splits balance a uniform keyspace exactly
    counts = [0] * 4
    for k in keys:
        counts[sm.shard_for(k)] += 1
    assert counts == [250, 250, 250, 250]
    # routing is monotonic in key order and hits every shard contiguously
    gids = [sm.shard_for(k) for k in keys]
    assert gids == sorted(gids)
    # a scan range touches exactly the contiguous groups that own it
    assert list(sm.shards_for_range(keys[0], keys[-1])) == [0, 1, 2, 3]
    assert list(sm.shards_for_range(keys[300], keys[400])) == [1]
    assert set(sm.shards_for_range(keys[200], keys[300])) >= {0, 1}
    # range_of boundaries agree with shard_for
    for g in range(4):
        lo, hi = sm.range_of(g)
        if lo is not None:
            assert sm.shard_for(lo) == g
        if hi is not None:
            assert sm.shard_for(hi) == g + 1


def test_shardmap_even_covers_byte_space():
    sm = ShardMap.even(8, b"\x00" * 4, b"\xff" * 4)
    assert sm.n_shards == 8
    assert sm.splits == sorted(sm.splits)
    seen = {sm.shard_for(bytes([b, 0, 0, 0])) for b in range(256)}
    assert seen == set(range(8))
    assert ShardMap.even(1).splits == []
    with pytest.raises(ValueError):
        ShardMap.even(0)


# ------------------------------------------------- cross-shard guarantees
def test_cross_shard_session_read_your_writes(tmp_path):
    keys = _keys(400)
    sc = _mk(tmp_path, keys)
    s = sc.session()
    ka = keys[10]      # shard 0
    kb = keys[390]     # shard 3
    assert sc.shard_map.shard_for(ka) != sc.shard_map.shard_for(kb)
    s.put(ka, b"A1")
    # read-your-writes across the boundary: the write advanced only
    # shard 0's token, and the shard-3 read is governed by shard 3's —
    # yet both reads must see their own shard's latest session state
    assert s.get(ka) == b"A1"
    s.put(kb, b"B1")
    assert s.get(kb) == b"B1"
    assert s.get(ka) == b"A1"
    # the token is a per-shard vector, not one scalar
    vec = s.vector()
    assert set(vec) == {0, 3}
    assert all(v > 0 for v in vec.values())
    # monotonic reads: a second session observing the same keys can
    # never read older values after newer ones
    s.put(ka, b"A2")
    assert s.get(ka) == b"A2"
    sc.destroy()


def test_scatter_gather_scan_byte_equal_reference(tmp_path):
    keys = _keys(300)
    items = [(k, b"v:" + k) for k in keys]
    sc = _mk(tmp_path, keys, sub="sharded")
    assert sc.put_many(items, window=48) == len(items)
    ref = Cluster(n=3, engine="nezha", workdir=str(tmp_path / "ref"),
                  seed=7)
    ref.elect()
    ref.put_many(items, window=48)
    lo, hi = keys[0], keys[-1]
    got = sc.scan(lo, hi, LINEARIZABLE)
    exp = ref.scan(lo, hi, LINEARIZABLE)
    assert got == exp              # byte-equal, globally key-ordered
    assert len(got) == len(items)
    # a sub-range crossing one split only touches those shards and still
    # matches the reference
    assert sc.scan(keys[100], keys[200], LINEARIZABLE) == \
        ref.scan(keys[100], keys[200], LINEARIZABLE)
    sc.destroy()
    ref.destroy()


def test_put_many_interleaves_shards(tmp_path):
    """All groups' logs must grow during ONE put_many — the pipes run
    concurrently over shared ticks, not shard-serial."""
    keys = _keys(240)
    sc = _mk(tmp_path, keys, n_shards=3)
    items = [(k, b"x" * 32) for k in keys]
    done = sc.put_many(items, window=48)
    assert done == len(items)
    per_shard = [sc.groups[g].leader().last_applied for g in range(3)]
    assert all(applied >= 80 for applied in per_shard)
    # every key readable where it was routed
    for k in (keys[0], keys[120], keys[239]):
        assert sc.get(k, LINEARIZABLE) == b"x" * 32
    sc.destroy()


# ----------------------------------------------------------------- chaos
def test_one_shard_leader_kill_others_keep_serving(tmp_path):
    keys = _keys(200)
    sc = _mk(tmp_path, keys, seed=11)
    items = [(k, b"seed:" + k) for k in keys]
    sc.put_many(items, window=48)
    dead = sc.kill_leader(group=1)
    assert sc.groups[1].leader() is None     # group 1 is headless...
    # ...while the other groups serve reads and writes immediately
    assert sc.get(keys[10], LINEARIZABLE) == b"seed:" + keys[10]
    assert sc.put(keys[190], b"still-writable") > 0
    assert sc.get(keys[190], LINEARIZABLE) == b"still-writable"
    # the killed group recovers on its own (remaining 2/3 quorum)
    assert sc.groups[1].elect() is not None
    assert sc.get(keys[60], LINEARIZABLE) == b"seed:" + keys[60]
    sc.groups[1].restart(dead)
    sc.destroy()


def test_sharded_chaos_schedule_zero_violations(tmp_path):
    """Tier-1 gate: a seeded kill of ONE shard's leader under the checked
    workload — zero linearizability/session violations, and the timeline
    records which group each fault hit."""
    n_keys = 120
    keys = [_key(i) for i in range(n_keys)]
    sc = _mk(tmp_path, keys, seed=13)
    spec = WorkloadSpec(n_ops=120, n_keys=n_keys, vsize=64, seed=3,
                        virtual_time=True,
                        tenants=(Tenant("lin", 1.0, "A", LINEARIZABLE),))
    chaos = ChaosSchedule.kill_and_recover(at=0.3, restart_at=0.7,
                                           seed=3, group=1)
    rep = run_workload(sc, spec, chaos=chaos)
    assert rep.violations == []
    assert [e["action"] for e in rep.timeline] == ["kill_leader",
                                                   "restart"]
    assert all(e["group"] == 1 for e in rep.timeline)
    sc.destroy()


# ----------------------------------------------------------------- trace
def test_trace_put_many_one_root_per_shard_subtrees(tmp_path):
    keys = _keys(240)
    sc = _mk(tmp_path, keys, n_shards=3, seed=5)
    t = sc.enable_tracing()
    try:
        items = [(k, b"tv:" + k) for k in keys]
        assert sc.put_many(items, window=48) == len(items)
    finally:
        sc.disable_tracing()
    roots = t.roots("put_many")
    assert len(roots) == 1
    root = roots[0]
    assert root.tags["shards"] == 3
    kids = [s for s in t.children(root.sid) if s.name == "put_many.shard"]
    assert sorted(s.tags["shard"] for s in kids) == [0, 1, 2]
    for kid in kids:
        names = {s.name for s in t.subtree(kid.sid)}
        # each shard's subtree holds that group's full persistence story
        assert "follower.append" in names
        assert "apply" in names
    # events are keyed by (group, node) wire address, so the causality
    # auditor's per-node state is per-group: no cross-group confusion
    assert audit(t.events) == []
    nodes = {e["node"] for e in t.events if isinstance(e["node"], tuple)}
    assert {g for g, _ in nodes} == {0, 1, 2}
    sc.destroy()


# --------------------------------------------------------------- metrics
def test_registry_shard_labels_and_health_report(tmp_path):
    keys = _keys(200)
    sc = _mk(tmp_path, keys, seed=9)
    sc.put_many([(k, b"m" * 16) for k in keys], window=48)
    reg = sc.registry()
    scrape = reg.scrape()
    ups = [s for s in scrape["repro_node_up"]["samples"]]
    shards_seen = {s["labels"]["shard"] for s in ups}
    assert shards_seen == {"0", "1", "2", "3"}
    assert all(s["value"] == 1 for s in ups)
    # shared-net counters appear once, unlabeled by shard
    net = scrape["repro_net_msgs_total"]["samples"]
    assert {s["labels"].get("outcome") for s in net} == {"sent",
                                                         "dropped"}
    assert all("shard" not in s["labels"] for s in net)
    text = sc.prometheus_text()
    assert 'shard="3"' in text and 'shard="0"' in text
    hr = sc.health_report()
    assert hr["n_shards"] == 4
    assert [s["shard"] for s in hr["shards"]] == [0, 1, 2, 3]
    for s in hr["shards"]:
        assert s["leader"] is not None
        assert "leader" in s["roles"].values()
    sc.destroy()
