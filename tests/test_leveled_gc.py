"""Leveled garbage collection: the run hierarchy's load-bearing claims.

  * bounded work: one GC cycle rewrites O(active segment) bytes, not
    O(total store) — the paper's 'leveled garbage collection' win
  * level merges are incremental, crash-safe (manifest swap), and keep
    every run's (last_index, last_term) Raft boundary consistent
  * the streaming k-way scan and bloom-gated gets are byte-identical to
    a flat last-writer-wins replay across random workloads
  * satellite guards: O(1) truncation via the index->offset map, empty
    apply_batch, index-map pruning at the GC boundary
"""
import os
import tempfile

import pytest
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback
    from repro.testing.minihyp import (HealthCheck, given, settings,
                                       strategies as st)

from repro.core.engines import NezhaEngine, NezhaNoGCEngine
from repro.core.metrics import Metrics
from repro.core.valuelog import KIND_PUT, LogEntry


def put(eng, key, value, term=1, apply=True):
    idx = getattr(eng, "_t_index", 0) + 1
    eng._t_index = idx
    e = LogEntry(term, idx, KIND_PUT, key, value)
    off = eng.append(e)
    if apply:          # raft applies committed entries only; an entry that
        eng.apply(e, off)   # may later be truncated must stay unapplied
    return idx


def flush_active(eng, step=256):
    """One GC cycle only (active -> L0 run), no level merges."""
    eng.start_gc()
    while not eng.gc_completed:
        eng.gc_step(step)


def make_runs(eng, n_runs, keys_per_run, vsize=256, key_space=None):
    """Load n_runs GC cycles; returns the last-writer-wins model dict."""
    model = {}
    seq = 0
    for _ in range(n_runs):
        for _ in range(keys_per_run):
            if key_space is not None:
                k = key_space[seq % len(key_space)]
            else:
                k = f"key{seq:06d}".encode()
            v = bytes([seq % 256]) * vsize
            put(eng, k, v)
            model[k] = v
            seq += 1
        flush_active(eng)
    return model


# ------------------------------------------------------- bounded GC work
def test_gc_cycle_work_is_bounded_not_proportional_to_total_data():
    """With total data >= 4x gc_threshold, bytes rewritten by one GC cycle
    (gc_sorted / flush) must not scale with store size.  The monolithic
    design rewrote the whole sorted store every cycle."""
    wd = tempfile.mkdtemp()
    m = Metrics()
    eng = NezhaEngine(wd, m, gc_threshold=64 << 10, gc_batch=64)
    n, vsize = 1024, 1024          # ~1 MiB total = 16x the threshold
    for i in range(n):
        put(eng, f"key{i:06d}".encode(), bytes([i % 256]) * vsize)
        eng.post_op()
    flushes = m.gc_flush_bytes_per_cycle()
    assert len(flushes) >= 8, flushes
    total = eng.leveled.total_bytes() + eng.active.vlog.size
    assert total >= 4 * eng.gc_threshold
    # every cycle's flush is O(active segment): within 2x of the smallest
    # and far below total store size
    assert max(flushes) <= 2 * max(min(flushes), 1), flushes
    assert max(flushes) < total / 4, (max(flushes), total)
    # the hierarchy actually leveled up (merges ran, and are accounted)
    assert m.write_bytes.get("gc_level_merge", 0) > 0
    assert any(lvl >= 1 for lvl in eng.leveled.level_shape()), \
        eng.leveled.level_shape()
    # correctness after all that churn
    assert eng.get(b"key001023") == bytes([1023 % 256]) * vsize
    assert len(eng.scan(b"key000000", b"key999999")) == n
    eng.close()


def test_run_boundaries_strictly_newest_first():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=100)
    make_runs(eng, 5, 30)
    lis = [r.last_index for r in eng.leveled.runs]
    assert lis == sorted(lis, reverse=True) and len(set(lis)) == len(lis)
    assert eng.leveled.boundary == (lis[0], eng.leveled.runs[0].last_term)
    eng.close()


# -------------------------------------------------- crash mid-level-merge
@settings(max_examples=8, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(st.integers(min_value=0, max_value=40),
       st.integers(min_value=2, max_value=5))
def test_crash_mid_level_merge_recovers_consistent_manifest(merge_steps,
                                                            n_runs):
    """Kill the engine mid-level-merge: the manifest must recover to the
    pre-merge run set (inputs intact, partial output discarded), boundaries
    must respect Raft recency order, and no data may be lost."""
    wd = tempfile.mkdtemp()
    keys = [f"k{i:03d}".encode() for i in range(25)]   # forced overwrites
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=2)
    model = make_runs(eng, n_runs, 20, vsize=64, key_space=keys)
    runs_before = {r.rid: (r.level, r.last_index, r.last_term)
                   for r in eng.leveled.runs}
    boundary_before = eng.leveled.boundary
    level = eng.leveled.needs_merge()
    assert level is not None
    eng.start_level_merge(level)
    eng.merge_step(merge_steps)     # partial progress, then "crash"
    if eng._merge is None:          # tiny workload: merge already finished
        runs_before = {r.rid: (r.level, r.last_index, r.last_term)
                       for r in eng.leveled.runs}
    eng.close()

    eng2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=2)
    eng2.recover()
    # manifest: exactly the committed runs survive, no orphan files
    assert {r.rid: (r.level, r.last_index, r.last_term)
            for r in eng2.leveled.runs} == runs_before
    on_disk = {f for f in os.listdir(wd) if f.startswith("run_")}
    expected = {os.path.basename(p) for r in eng2.leveled.runs
                for p in (r.path, r.meta_path)}
    assert on_disk == expected, (on_disk, expected)
    # Raft boundaries: newest-first, strictly decreasing, store boundary
    # is the newest seal point
    lis = [r.last_index for r in eng2.leveled.runs]
    assert lis == sorted(lis, reverse=True) and len(set(lis)) == len(lis)
    assert eng2.leveled.boundary == boundary_before
    # no data lost; the merge redo converges to the same answers
    assert dict(eng2.scan(b"", b"\xff" * 8)) == model
    eng2.run_gc_to_completion()
    assert dict(eng2.scan(b"", b"\xff" * 8)) == model
    for k, v in model.items():
        assert eng2.get(k) == v
    eng2.close()


def test_crash_between_manifest_commit_and_gc_state_write():
    """finish_gc commits the run to the manifest before rewriting
    gc_state.json as complete.  A crash in that window must NOT re-add the
    run on recovery (the flush IS committed; only cleanup remained)."""
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    for i in range(120):
        put(eng, f"key{i:04d}".encode(), bytes([i]) * 64)
    eng.start_gc()
    orig_add = eng.leveled.add_l0

    def crash_after_commit(run, boundary):
        orig_add(run, boundary)
        raise RuntimeError("simulated crash")

    eng.leveled.add_l0 = crash_after_commit
    with pytest.raises(RuntimeError):
        eng.run_gc_to_completion()
    eng.close()

    eng2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    eng2.recover()
    assert eng2.gc_completed
    assert len(eng2.leveled.runs) == 1          # NOT duplicated
    assert eng2.leveled.total_keys() == 120
    assert eng2.leveled.boundary[0] == 120
    assert len(eng2.scan(b"", b"\xff" * 8)) == 120
    # the engine keeps working: new writes + another full GC cycle
    eng2._t_index = 120
    put(eng2, b"post-crash", b"p")
    flush_active(eng2)
    assert eng2.get(b"post-crash") == b"p"
    assert eng2.get(b"key0050") == bytes([50]) * 64
    eng2.close()


def test_recover_tolerates_legacy_mid_gc_state_without_rid():
    """A mid-GC gc_state.json lacking 'rid' (older writer) must not crash
    recovery: a fresh run is allocated and the flush restarts from the
    barrier once raft replay re-applies the active segment."""
    import json
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    for i in range(100):
        put(eng, f"key{i:04d}".encode(), bytes([i]) * 64)
    eng.start_gc()
    for _ in range(3):
        eng.gc_step(16)
    eng.close()
    state_path = os.path.join(wd, "gc_state.json")
    with open(state_path) as f:
        state = json.load(f)
    del state["rid"]
    with open(state_path, "w") as f:
        json.dump(state, f)

    eng2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    entries, offsets, _, _ = eng2.recover()     # must not NameError
    assert eng2.gc_started and not eng2.gc_completed
    for e, off in zip(entries, offsets):        # raft replay (header-only)
        eng2.apply(e, off)
    eng2.run_gc_to_completion()
    assert eng2.leveled.total_keys() == 100
    assert eng2.get(b"key0042") == bytes([42]) * 64
    assert len(eng2.scan(b"", b"\xff" * 8)) == 100
    eng2.close()


def test_crash_during_snapshot_install_keeps_old_run_set():
    """install_payload must not delete the committed runs before the
    manifest swap: a crash mid-install leaves the OLD set authoritative
    and fully loadable (new half-installed files are orphans)."""
    from repro.core.storage import LeveledStore
    src_eng = NezhaEngine(tempfile.mkdtemp(), Metrics(),
                          gc_threshold=1 << 60, level_fanout=100)
    make_runs(src_eng, 2, 20, vsize=64)
    payload = src_eng.leveled.snapshot_payload()

    wd = tempfile.mkdtemp()
    dst = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=100)
    old_model = make_runs(dst, 1, 15, vsize=32)
    store = dst.leveled
    old_rids = {r.rid for r in store.runs}
    orig_persist = LeveledStore._persist_manifest
    calls = {"n": 0}

    def crash_at_swap(self):
        calls["n"] += 1
        if calls["n"] > 1:               # call 1 reserves the rids; the
            raise RuntimeError("crash")  # next call is the swap
        orig_persist(self)

    store._persist_manifest = crash_at_swap.__get__(store)
    with pytest.raises(RuntimeError):
        store.install_payload(payload, *src_eng.leveled.boundary)
    dst.close()

    dst2 = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    dst2.recover()   # must NOT raise FileNotFoundError
    assert {r.rid for r in dst2.leveled.runs} == old_rids
    assert dict(dst2.scan(b"", b"\xff" * 8)) == old_model
    on_disk = {f for f in os.listdir(wd) if f.startswith("run_")}
    expected = {os.path.basename(p) for r in dst2.leveled.runs
                for p in (r.path, r.meta_path)}
    assert on_disk == expected   # half-installed orphans pruned
    dst2.close()
    src_eng.close()


# ------------------------------------------------------- A/B equivalence
@settings(max_examples=10, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(st.lists(st.tuples(st.binary(min_size=1, max_size=6),
                          st.binary(min_size=0, max_size=48)),
                min_size=1, max_size=150),
       st.integers(min_value=5, max_value=40))
def test_leveled_reads_match_flat_replay(ops, gc_every):
    """Property: leveled scan()/get() are byte-identical to a flat
    last-writer-wins replay, with GC cycles + level merges interleaved at
    arbitrary points in the workload."""
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=2)
    model = {}
    for i, (k, v) in enumerate(ops):
        put(eng, k, v)
        model[k] = v
        if (i + 1) % gc_every == 0:
            flush_active(eng, step=7)   # odd step: exercises partial slices
            if eng.leveled.needs_merge() is not None:
                eng.run_gc_to_completion()
    assert eng.scan(b"", b"\xff" * 8) == sorted(model.items())
    for k, v in model.items():
        assert eng.get(k) == v
    assert eng.get(b"\x00absent\x00") is None
    eng.close()


def test_point_get_skips_runs_via_bloom():
    wd = tempfile.mkdtemp()
    m = Metrics()
    eng = NezhaEngine(wd, m, gc_threshold=1 << 60, level_fanout=100)
    make_runs(eng, 4, 25)
    assert len(eng.leveled.runs) == 4
    skips_before = m.bloom_skips
    reads_before = m.read_bytes.get("sorted_point", 0)
    for i in range(20):
        assert eng.get(f"absent{i:04d}".encode()) is None
    # every absent get was rejected by run blooms with zero run I/O
    # (~1% fp rate; 20 keys x 4 runs => comfortably > 60 skips)
    assert m.bloom_skips - skips_before >= 60
    assert m.read_bytes.get("sorted_point", 0) == reads_before
    eng.close()


# ------------------------------------------------------------ satellites
def test_truncate_from_uses_offset_map_not_log_scan():
    for cls in (NezhaEngine, NezhaNoGCEngine):
        wd = tempfile.mkdtemp()
        m = Metrics()
        kw = {"gc_threshold": 1 << 60} if cls is NezhaEngine else {}
        eng = cls(wd, m, **kw)
        for i in range(30):
            put(eng, f"key{i:04d}".encode(), bytes([i]) * 100)
        for i in range(30, 50):     # uncommitted tail: appended, not applied
            put(eng, f"key{i:04d}".encode(), bytes([i]) * 100, apply=False)
        seq_before = m.read_bytes.get("valuelog_seq", 0)
        eng.truncate_from(31)
        # O(1) lookup: truncation must NOT sequentially scan the vlog
        assert m.read_bytes.get("valuelog_seq", 0) == seq_before
        # replacement entries land where the old tail was
        eng._t_index = 30
        put(eng, b"replay", b"x", term=2)
        assert eng.get(b"replay") == b"x"
        assert eng.get(b"key0045") is None   # truncated, never applied
        if cls is NezhaEngine:   # the index map was pruned past the cut
            assert max(eng._seg_of_index) == 31
        eng.close()


def test_seg_of_index_pruned_at_gc_boundary():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    for i in range(100):
        put(eng, f"key{i:04d}".encode(), b"v" * 64)
    assert len(eng._seg_of_index) == 100
    flush_active(eng)
    # indices <= boundary lived in the destroyed segment: map is empty now
    assert len(eng._seg_of_index) == 0
    assert eng.active.tag not in ()  # active rotated; stale tag dropped
    assert len(eng._last_by_tag) <= 1
    for i in range(100, 130):
        put(eng, f"key{i:04d}".encode(), b"v" * 64)
    assert len(eng._seg_of_index) == 30
    assert eng.get(b"key0005") == b"v" * 64   # GC'd data still served
    eng.close()


def test_apply_batch_tolerates_empty_pairs():
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60)
    eng.apply_batch([])          # must not raise (pairs[-1] used to)
    put(eng, b"k", b"v")
    eng.apply_batch([])
    assert eng.get(b"k") == b"v"
    eng.close()


def test_lagging_follower_catches_up_via_run_set_snapshot():
    """Cluster-level: a partitioned follower falls behind the leader's GC
    boundary; on heal, Raft ships the leveled run SET (not a monolithic
    file) and the follower converges to identical reads."""
    import tempfile as tf
    from repro.core.cluster import Cluster
    wd = tf.mkdtemp()
    c = Cluster(n=3, engine="nezha", workdir=wd, seed=3,
                engine_kwargs={"gc_threshold": 24 << 10, "level_fanout": 2})
    ld = c.elect()
    lagger = [i for i in range(3) if i != ld.nid][0]
    c.net.partition(ld.nid, lagger)
    c.net.partition(lagger, [i for i in range(3)
                             if i not in (ld.nid, lagger)][0])
    items = [(f"user{i:06d}".encode(), bytes([i % 256]) * 512)
             for i in range(300)]
    c.put_many(items)
    eng = c.engines[ld.nid]
    eng.run_gc_to_completion()
    assert len(eng.leveled.runs) >= 1 and eng.gc_count >= 2
    for e in c.engines:
        e.post_op()
    c.net.heal()
    for _ in range(3000):
        c.tick()
        if c.nodes[lagger].last_applied >= ld.commit_index and \
                c.engines[lagger].leveled.runs:
            break
    fol = c.engines[lagger]
    assert [r.last_index for r in fol.leveled.runs] == \
        [r.last_index for r in eng.leveled.runs]
    assert fol.scan(b"", b"\xff" * 8) == eng.scan(b"", b"\xff" * 8)
    c.destroy()


def test_snapshot_ships_run_set_and_installs():
    """InstallSnapshot payload is the whole run hierarchy; the follower
    reconstructs every run with its level + Raft boundary."""
    wd = tempfile.mkdtemp()
    eng = NezhaEngine(wd, Metrics(), gc_threshold=1 << 60, level_fanout=100)
    model = make_runs(eng, 3, 40, vsize=128)
    li, lt, payload = eng.snapshot()
    assert li == eng.leveled.boundary[0] and len(payload) == 3
    wd2 = tempfile.mkdtemp()
    fol = NezhaEngine(wd2, Metrics(), gc_threshold=1 << 60)
    for i in range(10):
        put(fol, f"stale{i}".encode(), b"s")    # superseded local state
    fol.install_snapshot(li, lt, payload)
    assert len(fol.leveled.runs) == 3
    assert [r.last_index for r in fol.leveled.runs] == \
        [r.last_index for r in eng.leveled.runs]
    assert dict(fol.scan(b"", b"\xff" * 8)) == model
    assert fol.get(b"stale3") is None
    # and the installed state survives a restart via the manifest
    fol.close()
    fol2 = NezhaEngine(wd2, Metrics(), gc_threshold=1 << 60)
    _, _, si, st_ = fol2.recover()
    assert (si, st_) == (li, lt)
    assert dict(fol2.scan(b"", b"\xff" * 8)) == model
    fol2.close()
    eng.close()
