"""Serving engine: continuous batching correctness + Nezha cache GC."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import forward, init_params
from repro.serve.engine import ServingEngine

CFG = get("smollm_135m", smoke=True).replace(param_dtype="float32",
                                             kv_block_size=8)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def ref_generate(prompt, n):
    toks = list(prompt)
    for _ in range(n + 1):
        logits, _ = forward(PARAMS, jnp.asarray([toks]), CFG, mode="train")
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_continuous_batching_matches_reference():
    eng = ServingEngine(CFG, PARAMS, max_slots=3, max_seq=64, seed=0,
                        scramble_blocks=True)
    prompts = [[5, 9, 2, 7], [1, 2, 3], [11, 4, 6, 8, 10], [3, 3, 3], [9, 1]]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    total = eng.run_until_drained()
    assert len(eng.finished) == len(prompts)
    assert total >= sum(r.max_new for r in reqs)
    for r in eng.finished:
        exp = ref_generate(r.prompt, r.max_new)
        assert r.out[:r.max_new] == exp[:r.max_new], (r.rid, r.out, exp)


def test_fragmentation_and_compaction():
    eng = ServingEngine(CFG, PARAMS, max_slots=2, max_seq=64, seed=1,
                        scramble_blocks=True)
    for i in range(4):
        eng.submit([1 + i, 2, 3], max_new=4)
    eng.run_until_drained()
    assert eng.fragmentation() > 0.3          # scrambled tables
    eng.compact(backend="reference")
    assert eng.fragmentation() == 0.0         # identity layout restored
    # correctness preserved after compaction
    r = eng.submit([5, 9, 2, 7], max_new=5)
    eng.run_until_drained()
    assert r.out[:5] == ref_generate([5, 9, 2, 7], 5)[:5]


def test_compaction_with_pallas_interpret_kernel():
    eng = ServingEngine(CFG, PARAMS, max_slots=2, max_seq=32, seed=2,
                        scramble_blocks=True)
    eng.submit([4, 2], max_new=3)
    eng.run_until_drained()
    eng.compact(backend="pallas_interpret")   # the actual GC kernel
    assert eng.fragmentation() == 0.0
    r = eng.submit([4, 2], max_new=3)
    eng.run_until_drained()
    assert r.out[:3] == ref_generate([4, 2], 3)[:3]


def test_mid_stream_admission():
    """A request admitted while another is mid-decode must not corrupt it."""
    eng = ServingEngine(CFG, PARAMS, max_slots=2, max_seq=64, seed=3,
                        scramble_blocks=True)
    r1 = eng.submit([7, 7, 7], max_new=8)
    for _ in range(3):
        eng.step()
    r2 = eng.submit([1, 2, 3, 4], max_new=4)
    eng.run_until_drained()
    assert r1.out[:8] == ref_generate([7, 7, 7], 8)[:8]
    assert r2.out[:4] == ref_generate([1, 2, 3, 4], 4)[:4]
