PY := PYTHONPATH=src python

.PHONY: test smoke chaos crash heal trace shard bench bench-full

test:
	$(PY) -m pytest -x -q

# tiny all-engine benchmark gate (also: pytest -m smoke)
smoke:
	$(PY) -m benchmarks.run --smoke

# fuller seeded chaos schedules (kill/isolate/lossy/gc_storm) + checker
chaos:
	$(PY) -m pytest -q -m chaos

# exhaustive crash-point sweeps at a longer workload than the default
# test run: every numbered I/O op x {drop,torn,lost_rename}, plus the
# full-cluster-restart durability gate
crash:
	CRASHPOINT_N_OPS=48 $(PY) -m pytest -q -m crashpoint

# self-healing membership suite at a wider config-change-window sweep
# than the tier-1 default (add learner -> promote -> remove voter, fleet
# kill -9 at every sampled I/O index in the window)
heal:
	MEMBER_SWEEP_N=64 $(PY) -m pytest -q -m membership

# end-to-end tracing suite + the persistence-waterfall figure (writes
# benchmarks/BENCH_fig_trace.json and prints one put's waterfall)
trace:
	$(PY) -m pytest -q -m trace
	$(PY) -m benchmarks.fig_trace

# multi-Raft sharded keyspace suite + the shard-scaling figure (writes
# BENCH_fig_shard.json: put throughput at 1/2/4 shards, scatter-gather
# scan equality, one-shard chaos leg)
shard:
	$(PY) -m pytest -q -m shard
	$(PY) -m benchmarks.fig_shard

bench:
	$(PY) -m benchmarks.run

bench-full:
	REPRO_BENCH_FULL=1 $(PY) -m benchmarks.run
