PY := PYTHONPATH=src python

.PHONY: test smoke chaos bench bench-full

test:
	$(PY) -m pytest -x -q

# tiny all-engine benchmark gate (also: pytest -m smoke)
smoke:
	$(PY) -m benchmarks.run --smoke

# fuller seeded chaos schedules (kill/isolate/lossy/gc_storm) + checker
chaos:
	$(PY) -m pytest -q -m chaos

bench:
	$(PY) -m benchmarks.run

bench-full:
	REPRO_BENCH_FULL=1 $(PY) -m benchmarks.run
