"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the loop-corrected per-device HLO
analysis (repro.launch.hlo_analysis):

  compute term    = flops_per_dev / 197 TFLOP/s (bf16, TPU v5e)
  memory term     = hbm_bytes_per_dev / 819 GB/s
  collective term = wire_bytes_per_dev / 50 GB/s/link

  MODEL_FLOPS = 6*N*D (train) | 2*N*D (prefill) | 2*N_active*B (decode),
  ratio = MODEL_FLOPS_per_dev / HLO_flops_per_dev  (useful-compute fraction)
  roofline_frac = useful compute time / max(term)  (the score per cell)
"""
from __future__ import annotations

import json
import pathlib
import sys

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link

HERE = pathlib.Path(__file__).resolve().parent
RESULTS = HERE / "results" / "dryrun.json"

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str, params: int, active: int,
                grad_accum_note: str = "") -> float:
    D = SHAPE_TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * params * D if active == params else 6.0 * active * D
    N = active if active != params else params
    return 2.0 * N * D


def bottleneck_hint(dom: str, arch: str, shape: str, ratio: float) -> str:
    if ratio < 0.15:
        return ("TP axis unusable by this arch's head/width factors -> "
                "replicated compute; reshard (seq-parallel attention) or "
                "shrink the model axis")
    if dom == "compute":
        return "compute-bound: cut remat recompute (policy/accum) or raise per-chip utilization"
    if dom == "memory":
        return "HBM-bound: fuse/flash the attention reads, larger tiles, bf16 residuals"
    return "collective-bound: overlap AG/RS with compute, shrink FSDP gather volume (accum), int8 grad compression"


def build_table(mesh: str = "16x16", layout: str = "paged",
                variant: str = "base"):
    data = json.loads(RESULTS.read_text())
    rows = []
    for key, v in sorted(data.items()):
        arch, shape, m, lay, var = key.split("|")
        if m != mesh or lay != layout or var != variant:
            continue
        if v.get("status") == "skipped":
            rows.append({"arch": arch, "shape": shape, "skipped":
                         v["reason"]})
            continue
        if v.get("status") != "ok":
            rows.append({"arch": arch, "shape": shape,
                         "skipped": f"ERROR {v.get('error')}"})
            continue
        pd = v["per_device"]
        n_dev = v["n_devices"]
        t_c = pd["flops"] / PEAK_FLOPS
        t_m = pd["hbm_bytes"] / HBM_BW
        t_x = pd["collective_bytes"] / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        mf = model_flops(arch, shape, v["model"]["params"],
                         v["model"]["active_params"])
        mf_dev = mf / n_dev
        ratio = mf_dev / max(pd["flops"], 1)
        useful_t = mf_dev / PEAK_FLOPS
        frac = useful_t / max(max(terms.values()), 1e-30)
        rows.append({
            "arch": arch, "shape": shape, "mesh": m,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom, "model_flops": mf,
            "useful_ratio": ratio, "roofline_frac": frac,
            "peak_gib": v["bytes_per_device"]["peak_live_est"] / 2 ** 30,
            "hint": bottleneck_hint(dom, arch, shape, ratio),
        })
    return rows


def render_markdown(rows) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| 6ND/HLO | roofline frac | peak GiB | what moves it |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"skip | — | {r['skipped'][:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {r['peak_gib']:.1f} | "
            f"{r['hint'][:70]} |")
    return "\n".join(out)


def run():
    """CSV rows for benchmarks.run: name, us_per_call(=bound step us), info."""
    rows_out = []
    for mesh in ["16x16", "2x16x16"]:
        for r in build_table(mesh=mesh):
            if "skipped" in r:
                continue
            bound_us = max(r["t_compute_s"], r["t_memory_s"],
                           r["t_collective_s"]) * 1e6
            rows_out.append((f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                             bound_us,
                             f"dom={r['dominant']};frac="
                             f"{r['roofline_frac']:.3f};"
                             f"useful={r['useful_ratio']:.2f}"))
    return rows_out


def main():
    md = ["# Roofline — single-pod 16x16 (256 chips), baseline variant", "",
          render_markdown(build_table("16x16")), "",
          "# Roofline — multi-pod 2x16x16 (512 chips)", "",
          render_markdown(build_table("2x16x16"))]
    out = HERE / "results" / "roofline.md"
    out.write_text("\n".join(md))
    print("\n".join(md))


if __name__ == "__main__":
    main()
