"""Fig. 9 — put throughput/latency vs cluster size (3, 5, 7 nodes)."""
from __future__ import annotations

from benchmarks import common

SIZES = [3, 5, 7]
VSIZE = 4096
N_BYTES = (8 << 20) if common.FULL else (2 << 20)


def run(engines=None):
    rows = []
    for engine in engines or ["original", "nezha_nogc", "nezha"]:
        for n in SIZES:
            # GC deferred on the measured put path (see fig4 note)
            c = common.make_cluster(engine, n=n, gc_threshold=1 << 60)
            items = common.keys_values(max(N_BYTES // VSIZE, 64), VSIZE)
            dt, done = common.timed(c.put_many, items)
            rows.append((f"fig9_scale/{engine}/n{n}", 1e6 * dt / done,
                         f"ops_s={done / dt:.0f}"))
            common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
