"""Fig. READS — the consistency-tier ladder and follower-read scaling.

Two claims under measurement:

  1. The tier ladder prices reads correctly: LINEARIZABLE pays one
     heartbeat-quorum round per read when serial, ~1/B rounds per read
     when batched (one round confirms the whole queue), and LEASE pays
     ZERO rounds under a stable leader.  Evidence is read_report()'s
     quorum-round counters, not just wall clock.
  2. SESSION reads turn followers into read capacity: with run shipping
     (the NezhaEngine default) every follower holds the leader's exact
     sealed-run sets, so session scans are byte-equal with the leader and
     aggregate scan throughput scales with cluster size (n=3 and n=5 vs
     the leader-only baseline).

Scaling model: the cluster is a single-process discrete-event sim, so the
spread configuration cannot literally run nodes in parallel.  Session
reads do zero cross-node work (each node serves from its own engine), so
ideal-parallel aggregate throughput is computed from per-node busy time:
K scans spread round-robin over n nodes => K / max(per-node busy seconds).
The leader-only baseline is the same K scans all on the leader.
"""
from __future__ import annotations

import tempfile
import time
from collections import defaultdict

from benchmarks import common
from repro.core.client import LEASE, SESSION
from repro.core.cluster import Cluster

N_KEYS = 900 if common.FULL else 360
VSIZE = 512
N_GETS = 120 if common.FULL else 48
N_SCANS = 60 if common.FULL else 24
HI = b"\xff" * 11


def _load(nn: int, n_keys: int, vsize: int, gc_threshold: int, seed: int):
    wd = tempfile.mkdtemp(prefix=f"reads_n{nn}_")
    c = Cluster(n=nn, engine="nezha", workdir=wd, seed=seed,
                engine_kwargs={"gc_threshold": gc_threshold,
                               "gc_batch": 128, "level_fanout": 2})
    items = common.keys_values(n_keys, vsize)
    c.put_many(items)
    ld = c.elect()
    c.engines[ld.nid].run_gc_to_completion()
    c.drain_shipping()
    return c, items


def _snap(c: Cluster) -> list:
    """Per-node Metrics.snapshot() — counters are engine-lifetime
    cumulative, so every measured section works on deltas."""
    return [m.snapshot() for m in c.metrics]


def _delta(c: Cluster, snaps) -> list:
    return [m.delta(s) for m, s in zip(c.metrics, snaps)]


def _rounds_since(c: Cluster, snaps) -> int:
    return sum(d["read_quorum_rounds"] for d in _delta(c, snaps))


def run(n_keys=None, vsize=None, n_gets=None, n_scans=None, sizes=(3, 5),
        seed=13):
    n_keys = n_keys or N_KEYS
    vsize = vsize or VSIZE
    n_gets = n_gets or N_GETS
    n_scans = n_scans or N_SCANS
    gc_threshold = max((n_keys // 6) * vsize, 16 << 10)
    rows = []

    # ---- tier ladder: per-read cost at n=3 --------------------------------
    c, items = _load(3, n_keys, vsize, gc_threshold, seed)
    keys = [k for k, _ in items]
    sample = [keys[(i * 7919) % len(keys)] for i in range(n_gets)]

    s0 = _snap(c)
    dt, _ = common.timed(lambda: [c.get(k) for k in sample])
    rounds = _rounds_since(c, s0)
    rows.append(("fig_reads/linearizable", 1e6 * dt / n_gets,
                 f"ops_s={n_gets / dt:.0f};quorum_rounds={rounds}"
                 f";rounds_per_read={rounds / n_gets:.2f}"))

    s0 = _snap(c)
    batch = 16
    dt, _ = common.timed(lambda: [
        c.client.get_many(sample[i:i + batch])
        for i in range(0, n_gets, batch)])
    rounds = _rounds_since(c, s0)
    rows.append(("fig_reads/linearizable_batched", 1e6 * dt / n_gets,
                 f"ops_s={n_gets / dt:.0f};quorum_rounds={rounds}"
                 f";rounds_per_read={rounds / n_gets:.2f};batch={batch}"))

    c.get(sample[0], LEASE)        # may pay one round to (re)arm the lease
    s0 = _snap(c)
    dt, _ = common.timed(lambda: [c.get(k, LEASE) for k in sample])
    rounds = _rounds_since(c, s0)
    rows.append(("fig_reads/lease", 1e6 * dt / n_gets,
                 f"ops_s={n_gets / dt:.0f};quorum_rounds={rounds}"
                 f";rounds_per_read={rounds / n_gets:.2f}"))
    common.destroy(c)

    # ---- follower-read scaling: session scans at n=3 / n=5 ----------------
    for nn in sizes:
        c, _ = _load(nn, n_keys, vsize, gc_threshold, seed)
        ld = c.elect()
        ses = c.session()
        ses.observe(ld.last_applied)
        lscan = c.engines[ld.nid].scan(b"", HI)
        equal = all(c.scan(b"", HI, SESSION, session=ses, node=f) == lscan
                    for f in range(nn) if f != ld.nid)

        # leader-only baseline: every scan serializes through one node
        dt, _ = common.timed(lambda: [
            c.scan(b"", HI, SESSION, session=ses, node=ld.nid)
            for _ in range(n_scans)])
        base = n_scans / dt
        rows.append((f"fig_reads/n{nn}/leader_only", 1e6 * dt / n_scans,
                     f"scans_s={base:.0f};nodes=1"))

        # spread: round-robin over every live node, ideal-parallel
        # throughput = K / max per-node busy time (see module docstring)
        busy = defaultdict(float)
        s0 = _snap(c)      # isolate the spread loop from the equality
                           # check + baseline scans above (all session-tier)
        order = list(range(nn))
        for j in range(n_scans):
            nid = order[j % nn]
            t0 = time.perf_counter()
            c.scan(b"", HI, SESSION, session=ses, node=nid)
            busy[nid] += time.perf_counter() - t0
        agg = n_scans / max(busy.values())
        deltas = _delta(c, s0)
        fol_serves = sum(d["follower_serves"] for d in deltas)
        rows.append((
            f"fig_reads/n{nn}/session_spread",
            1e6 * max(busy.values()) / n_scans,
            f"scans_s={agg:.0f};nodes={nn};scaling_x={agg / base:.2f}"
            f";scan_equal={int(equal)};follower_serves={fol_serves}"
            f";session_stalls={sum(d['session_stalls'] for d in deltas)}"))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
