"""Fig. TAIL — open-loop tail latency per consistency tier, through chaos.

Every other figure is closed-loop mean ops/s; this one is the ROADMAP's
"millions of users" lens: Poisson arrivals at a fixed offered rate,
Zipfian key skew, YCSB mixes, latency read off HDR-style log-bucketed
histograms (p50/p99/p999), with the queue-delay vs service-time split
that closed-loop numbers structurally cannot see (coordinated omission).

Scenarios:
  * steady/<tier>   read-heavy YCSB-B at each consistency tier — how much
                    tail each rung of the ladder costs under no faults.
  * tenants/<name>  multi-tenant mix (OLTP writes + session-tier analytic
                    scans) sharing one cluster — cross-tenant tail
                    interference.
  * chaos/kill_leader   the same load with a seeded leader kill + restart
                    mid-run: p99 split into steady / fault / recovered
                    phases, plus zero-violation linearizability evidence.
  * chaos/mixed     a generated (seeded) schedule mixing leader isolation,
                    lossy windows and GC storms.

Every chaos row's {seed, schedule} is recorded into BENCH_fig_tail.json —
rerunning with those values reproduces the exact fault timeline (pinned
by tests/test_chaos_harness.py).
"""
from __future__ import annotations

import tempfile

from benchmarks import common
from repro.core.client import LEASE, LINEARIZABLE, SESSION
from repro.core.cluster import Cluster
from repro.core.workload import (ChaosSchedule, Tenant, WorkloadSpec,
                                 run_workload)

N_KEYS = 600 if common.FULL else 240
VSIZE = 512
N_OPS = 900 if common.FULL else 360
RATE = 800.0           # offered arrivals/s — below service capacity, so
                       # steady-state queues stay shallow and the chaos
                       # rows isolate the FAILOVER's queue, not overload


def _cluster(seed: int, n_keys: int, vsize: int) -> Cluster:
    wd = tempfile.mkdtemp(prefix="fig_tail_")
    return Cluster(n=3, engine="nezha", workdir=wd, seed=seed,
                   engine_kwargs={"gc_threshold": max(
                       (n_keys // 4) * vsize, 24 << 10),
                       "gc_batch": 128, "level_fanout": 2})


def _q(h, q: float) -> float:
    """Phase histograms can be legitimately empty (a fault window no op
    landed in); quantile() raises on empty, so report 0.0 instead."""
    return h.quantile(q) if h.n else 0.0


def _fmt(rep, label: str) -> str:
    h = rep.hist.get(label)
    q = rep.queue_hist.get(label)
    s = rep.service_hist.get(label)
    if h is None or h.n == 0:
        return "n=0"
    return (f"n={h.n};p50_us={h.quantile(.5):.0f}"
            f";p99_us={h.quantile(.99):.0f}"
            f";p999_us={h.quantile(.999):.0f}"
            f";queue_p99_us={_q(q, .99):.0f}"
            f";service_p99_us={_q(s, .99):.0f}")


def _chaos_row(name, rep, seed):
    """Phase p99s + the bounded-through-failover evidence the smoke gate
    asserts: recovered-phase p99 vs steady-phase p99, zero violations."""
    steady = rep.merged("steady")
    fault = rep.merged("fault")
    rec = rep.merged("recovered")
    base = max(_q(steady, .99), 1.0)
    ratio = _q(rec, .99) / base
    return (name, steady.mean(),
            f"violations={len(rep.violations)}"
            f";faults={len(rep.timeline)}"
            f";steady_p99_us={_q(steady, .99):.0f}"
            f";fault_p99_us={_q(fault, .99):.0f}"
            f";recovered_p99_us={_q(rec, .99):.0f}"
            f";p99_ratio={ratio:.2f}"
            f";refused={sum(rep.refused.values())}"
            f";achieved_rate={rep.achieved_rate:.0f}"
            f";chaos_seed={seed}")


def chaos_smoke(n_keys=100, vsize=256, n_ops=600, rate=600.0, seed=7):
    """One seeded kill-and-recover cycle at smoke scale.  The --smoke gate
    asserts on this row: zero linearizability/session violations through a
    leader kill, and recovered-phase p99 within 10x of steady-state p99.
    Latency runs on the VIRTUAL clock (service time = SimNet ticks *
    tick_us), so the p99s are a pure function of the seeds — CPU steal on
    a loaded host cannot flake the gate and one attempt suffices."""
    c = _cluster(seed, n_keys, vsize)
    spec = WorkloadSpec(rate=rate, n_ops=n_ops, n_keys=n_keys, vsize=vsize,
                        seed=seed, tenants=(Tenant("t", 1.0, "A"),),
                        virtual_time=True)
    rep = run_workload(c, spec, ChaosSchedule.kill_and_recover(seed=seed))
    row = _chaos_row("smoke_chaos/kill_leader", rep, seed)
    common.destroy(c)
    return [row]


def run(n_keys=None, vsize=None, n_ops=None, rate=None, seed=21,
        extras=None):
    n_keys = n_keys or N_KEYS
    vsize = vsize or VSIZE
    n_ops = n_ops or N_OPS
    rate = rate or RATE
    rows = []

    # ---- steady-state tier ladder -------------------------------------
    for tier in (LINEARIZABLE, LEASE, SESSION):
        c = _cluster(seed, n_keys, vsize)
        spec = WorkloadSpec(rate=rate, n_ops=n_ops, n_keys=n_keys,
                            vsize=vsize, seed=seed,
                            tenants=(Tenant("t", 1.0, "B", tier=tier),))
        rep = run_workload(c, spec)
        assert not rep.violations, rep.violations[:3]
        get = f"get:{tier}"
        rows.append((f"fig_tail/steady/{tier}",
                     rep.hist[get].mean() if get in rep.hist else 0.0,
                     _fmt(rep, get) + f";put_p99_us="
                     f"{rep.hist['put'].quantile(.99):.0f}"
                     f";achieved_rate={rep.achieved_rate:.0f}"))
        common.destroy(c)

    # ---- multi-tenant interference ------------------------------------
    c = _cluster(seed, n_keys, vsize)
    spec = WorkloadSpec(
        rate=rate, n_ops=n_ops, n_keys=n_keys, vsize=vsize, seed=seed,
        tenants=(Tenant("oltp", 2.0, "A", tier=LINEARIZABLE),
                 Tenant("scan", 1.0, "E", tier=SESSION)))
    rep = run_workload(c, spec)
    assert not rep.violations, rep.violations[:3]
    rows.append(("fig_tail/tenants/oltp",
                 rep.hist["oltp:put"].mean(),
                 _fmt(rep, "oltp:get:linearizable") + ";put_p99_us="
                 f"{rep.hist['oltp:put'].quantile(.99):.0f}"))
    rows.append(("fig_tail/tenants/scan",
                 rep.hist["scan:scan:session"].mean(),
                 _fmt(rep, "scan:scan:session")))
    common.destroy(c)

    # ---- chaos: one kill-and-recover cycle ----------------------------
    chaos_extra = {}
    c = _cluster(seed, n_keys, vsize)
    spec = WorkloadSpec(rate=rate, n_ops=n_ops, n_keys=n_keys, vsize=vsize,
                        seed=seed, tenants=(Tenant("t", 1.0, "A"),))
    chaos = ChaosSchedule.kill_and_recover(seed=seed)
    rep = run_workload(c, spec, chaos)
    rows.append(_chaos_row("fig_tail/chaos/kill_leader", rep, seed))
    chaos_extra["kill_leader"] = {"chaos": rep.chaos,
                                  "timeline": rep.timeline,
                                  "phases": {p: {"ops": rep.phase_ops[p]}
                                             for p in rep.phase_ops}}
    common.destroy(c)

    # ---- chaos: generated mixed schedule ------------------------------
    c = _cluster(seed, n_keys, vsize)
    spec = WorkloadSpec(rate=rate, n_ops=n_ops, n_keys=n_keys, vsize=vsize,
                        seed=seed,
                        tenants=(Tenant("rw", 2.0, "A"),
                                 Tenant("ro", 1.0, "C", tier=SESSION)))
    chaos = ChaosSchedule.generate(seed, n_cycles=2)
    rep = run_workload(c, spec, chaos)
    rows.append(_chaos_row("fig_tail/chaos/mixed", rep, seed))
    chaos_extra["mixed"] = {"chaos": rep.chaos, "timeline": rep.timeline}
    common.destroy(c)

    if extras is not None:
        extras["chaos"] = chaos_extra
    return rows


if __name__ == "__main__":
    extras = {}
    rows = run(extras=extras)
    common.emit(rows)
    common.write_artifact("fig_tail", rows, extra=extras)
