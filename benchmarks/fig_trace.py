"""Persistence waterfall — where does one put's durability actually go?

Traced runs (repro.core.trace) answer the paper's mechanism question
structurally instead of statistically: each client put opens a root
span, the context rides every AppendEntries, and the leader's + both
followers' fsyncs land INSIDE that put's subtree with their layer tag.
The figure reports, per engine:

  * the put critical path — fsyncs and value bytes on the LEADER under
    each put's root span, split by layer (nezha: ONE valuelog fsync,
    the Raft-log-IS-the-ValueLog design; original: the value pays both
    the raft_log append and the WAL),
  * the cluster-wide persistence bill for the same put (all nodes),
  * per-tier read paths (linearizable / lease / session) — bytes and
    read ops under each get's root span,
  * GC interference — how much gc.flush/gc.merge span time lands inside
    the put window once the store cycles,
  * reconciliation — io-span byte sums equal the Metrics counter deltas
    for the same window, category for category (asserted, not eyeballed).

smoke_gate() is CI gate #9: a traced chaos run (leader kill + lossy
window) audits to ZERO causality violations; every synced nezha put
carries exactly one value-log fsync on the leader critical path; and a
tracer left uninstalled costs nothing — the same-seed untraced run has
the identical SimNet trace, identical Metrics, and comparable wall time.
"""
from __future__ import annotations

import tempfile
import time

from benchmarks import common
from repro.core import trace
from repro.core.client import LEASE, LINEARIZABLE, SESSION
from repro.core.cluster import Cluster
from repro.core.workload import (ChaosSchedule, FaultEvent, Tenant,
                                 WorkloadSpec, run_workload)

N_PUTS = 48 if common.FULL else 16
VSIZE = 1024


def _sync_cluster(engine: str, seed: int = 7, **engine_kw) -> Cluster:
    wd = tempfile.mkdtemp(prefix=f"bench_trace_{engine}_")
    kw = {}
    if engine == "nezha":
        kw = {"gc_threshold": 1 << 60, "gc_batch": 128}
    kw.update(engine_kw)
    c = Cluster(n=3, engine=engine, workdir=wd, seed=seed, sync=True,
                engine_kwargs=kw)
    for eng in c.engines:
        if hasattr(eng, "db"):
            eng.db.memtable_limit = 256 << 10
            eng.db.l0_limit = 2
    c.elect()
    return c


def _crit(t: trace.Tracer, root, leader: int):
    """Leader-side persistence under one root span: (fsyncs-by-category,
    write-bytes-by-category)."""
    fsyncs: dict = {}
    wbytes: dict = {}
    for s in t.subtree(root.sid):
        if s.kind != "io" or s.node != leader:
            continue
        cat = s.tags.get("category", "?")
        if s.name == "io.fsync":
            fsyncs[cat] = fsyncs.get(cat, 0) + 1
        elif s.name == "io.write":
            wbytes[cat] = wbytes.get(cat, 0) + int(s.tags.get("bytes", 0))
    return fsyncs, wbytes


def _fmt_cats(d: dict) -> str:
    return ",".join(f"{k}:{v}" for k, v in sorted(d.items())) or "none"


def _reconcile(t: trace.Tracer, c: Cluster, before) -> bool:
    """Exact cross-check: io-span sums == Metrics deltas, per category."""
    sums = t.io_sums()
    for op, attr in (("write", "write_bytes"), ("read", "read_bytes")):
        want: dict = {}
        for m, b4 in zip(c.metrics, before):
            for cat, n in m.delta(b4)[attr].items():
                want[cat] = want.get(cat, 0) + n
        got = {cat: n for (o, cat), n in sums.items() if o == op and n}
        if got != {k: v for k, v in want.items() if v}:
            return False
    nspans = sum(1 for s in t.spans if s.name == "io.fsync")
    return nspans == sum(m.delta(b4)["fsyncs"]
                         for m, b4 in zip(c.metrics, before))


def waterfall(engine: str) -> tuple:
    """One row: the avg put critical path + cluster bill, reconciled."""
    c = _sync_cluster(engine)
    before = [m.snapshot() for m in c.metrics]
    t = c.enable_tracing()
    items = common.keys_values(N_PUTS, VSIZE)
    t0 = time.perf_counter()
    for k, v in items:
        c.put(k, v)
    dt = time.perf_counter() - t0
    ld = c.leader()
    c.disable_tracing()
    roots = t.roots("put")
    crit_f: dict = {}
    crit_b: dict = {}
    cluster_f = 0
    for root in roots:
        f, b = _crit(t, root, ld.nid)
        for k2, v2 in f.items():
            crit_f[k2] = crit_f.get(k2, 0) + v2
        for k2, v2 in b.items():
            crit_b[k2] = crit_b.get(k2, 0) + v2
        cluster_f += sum(1 for s in t.subtree(root.sid)
                         if s.name == "io.fsync")
    n = max(len(roots), 1)
    rec = _reconcile(t, c, before)
    row = (f"fig_trace_waterfall/{engine}", 1e6 * dt / n,
           f"puts={len(roots)}"
           f";crit_fsyncs_per_put={sum(crit_f.values()) / n:.2f}"
           f";crit_fsync_cats={_fmt_cats(crit_f)}"
           f";crit_write_bytes_per_put={sum(crit_b.values()) / n:.0f}"
           f";crit_write_cats={_fmt_cats(crit_b)}"
           f";cluster_fsyncs_per_put={cluster_f / n:.2f}"
           f";reconciled={int(rec)}"
           f";violations={len(trace.audit(t.events))}")
    common.destroy(c)
    return row


def read_paths() -> list:
    """Per-tier read rows: bytes + read ops under each get's root span."""
    c = _sync_cluster("nezha")
    items = common.keys_values(N_PUTS, VSIZE)
    for k, v in items:
        c.put(k, v)
    t = c.enable_tracing()
    rows = []
    sess = c.session()
    for tier, kw in ((LINEARIZABLE, {}), (LEASE, {}),
                     (SESSION, {"session": sess})):
        mark = len(t.spans)
        for k, v in items[: N_PUTS // 2]:
            assert c.get(k, tier, **kw) == v
        gets = [s for s in t.spans[mark:]
                if s.parent == 0 and s.name == "get"]
        rbytes = rops = 0
        for g in gets:
            for s in t.subtree(g.sid):
                if s.name == "io.read":
                    rbytes += int(s.tags.get("bytes", 0))
                    rops += 1
        n = max(len(gets), 1)
        rows.append((f"fig_trace_reads/{tier}", 0.0,
                     f"gets={len(gets)};read_bytes_per_get={rbytes / n:.0f}"
                     f";read_ops_per_get={rops / n:.2f}"))
    c.disable_tracing()
    common.destroy(c)
    return rows


def gc_interference() -> tuple:
    """Low-threshold cluster: how much GC span time lands inside the put
    window, and does the audit stay clean while GC interleaves."""
    c = _sync_cluster("nezha", gc_threshold=24 << 10, gc_batch=64)
    t = c.enable_tracing()
    items = common.keys_values(3 * N_PUTS, 1024)
    for k, v in items:
        c.put(k, v)
    c.disable_tracing()
    gc_spans = [s for s in t.spans if s.kind == "gc"]
    gc_ticks = sum((s.t1 or s.t0) - s.t0 for s in gc_spans)
    put_ticks = sum((s.t1 or s.t0) - s.t0 for s in t.roots("put"))
    viol = trace.audit(t.events)
    row = ("fig_trace_gc_interference/nezha", 0.0,
           f"gc_spans={len(gc_spans)};gc_ticks={gc_ticks}"
           f";put_ticks={put_ticks}"
           f";gc_share={gc_ticks / max(gc_ticks + put_ticks, 1):.3f}"
           f";violations={len(viol)}")
    common.destroy(c)
    return row


def smoke_gate() -> list:
    """CI gate #9 (see benchmarks/run.py smoke())."""
    rows = []
    # (a) traced chaos: leader kill + lossy window, zero violations
    wd = tempfile.mkdtemp(prefix="trace_gate_chaos_")
    c = Cluster(n=3, engine="nezha", workdir=wd, seed=17,
                engine_kwargs={"gc_threshold": 1 << 60})
    t = c.enable_tracing()
    spec = WorkloadSpec(rate=5000.0, n_ops=160, n_keys=64, vsize=256,
                        seed=5, tenants=(Tenant("t", 1.0, "A"),))
    sched = ChaosSchedule([FaultEvent(0.20, "kill_leader"),
                           FaultEvent(0.45, "restart", recovery=True),
                           FaultEvent(0.60, "lossy", 0.15),
                           FaultEvent(0.80, "heal_lossy", recovery=True)],
                          seed=17)
    rep = run_workload(c, spec, sched)
    c.disable_tracing()
    viol = trace.audit(t.events)
    faults = [e["kind"] for e in t.events if e["kind"] == "fault"]
    lossy_drops = c.net.drop_reasons.get("lossy", 0)
    rows.append(("smoke_trace/chaos_audit", 0.0,
                 f"causality_violations={len(viol)}"
                 f";history_violations={len(rep.violations)}"
                 f";faults_annotated={len(faults)}"
                 f";lossy_drops={lossy_drops}"
                 f";spans={len(t.spans)}"))
    common.destroy(c)

    # (b) put critical path: EXACTLY one value-log fsync per commit
    # window on the leader, for every synced nezha put
    c = _sync_cluster("nezha", seed=9)
    t = c.enable_tracing()
    for k, v in common.keys_values(12, 512, seed=2):
        c.put(k, v)
    ld = c.leader()
    c.disable_tracing()
    per_put = [_crit(t, r, ld.nid)[0].get("valuelog", 0)
               for r in t.roots("put")]
    rows.append(("smoke_trace/put_critical_path", 0.0,
                 f"puts={len(per_put)}"
                 f";vlog_fsyncs_min={min(per_put)}"
                 f";vlog_fsyncs_max={max(per_put)}"))
    common.destroy(c)

    # (c) disabled-tracer footprint: untraced same-seed run is identical
    # in simulation terms and not meaningfully slower
    sig = []
    walls = []
    for traced in (False, True):
        c2 = _sync_cluster("nezha", seed=13)
        c2.net.enable_trace()
        if traced:
            c2.enable_tracing()
        w0 = time.perf_counter()
        for k, v in common.keys_values(24, 512, seed=3):
            c2.put(k, v)
        walls.append(time.perf_counter() - w0)
        sig.append((list(c2.net.trace), c2.net.time,
                    [dict(m.write_bytes) for m in c2.metrics],
                    [m.fsyncs for m in c2.metrics]))
        c2.disable_tracing()
        common.destroy(c2)
    ratio = walls[1] / max(walls[0], 1e-9)
    rows.append(("smoke_trace/disabled_footprint", 0.0,
                 f"sim_identical={int(sig[0] == sig[1])}"
                 f";wall_ratio={ratio:.2f}"))
    return rows


def run():
    rows = [waterfall("nezha"), waterfall("original")]
    rows += read_paths()
    rows.append(gc_interference())
    rows += smoke_gate()
    return rows


if __name__ == "__main__":
    rows = run()
    common.emit(rows)
    path = common.write_artifact("fig_trace", rows)
    import sys
    print(f"# wrote {path}", file=sys.stderr)
    # one annotated waterfall for humans (also: examples/trace_put.py)
    c = _sync_cluster("nezha")
    t = c.enable_tracing()
    c.put(b"demo-key", b"demo-value" * 32)
    c.disable_tracing()
    (root,) = t.roots("put")
    print(trace.render_waterfall(t, root.sid), file=sys.stderr)
    common.destroy(c)
