"""Benchmark harness — one module per paper table/figure + the roofline
table from the dry-run.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  REPRO_BENCH_FULL=1 ... python -m benchmarks.run    # ~10x sizes
  python -m benchmarks.run --only fig4,roofline
  python -m benchmarks.run --smoke                   # tiny CI gate (tier-1)
"""
from __future__ import annotations

import argparse
import sys
import time


def smoke() -> int:
    """Tiny all-engine gate runnable in the tier-1 time budget.

    Asserts the four load-bearing claims survive the pipeline:
      1. nezha writes no more value bytes per user byte than original
         (the paper's >=3x -> 1x story),
      2. group commit actually cuts fsyncs: batch=32 uses < 1/4 the fsyncs
         of batch=1 on a small synced nezha run,
      3. leveled GC (fig10 at smoke scale) keeps per-cycle flush work flat
         while sustaining puts through multiple GC cycles,
      4. run shipping (fig_runship at smoke scale) keeps follower GC flush
         bytes at ~0 and cuts cluster-wide GC rewrite work vs the local-GC
         baseline, with leader/follower scans byte-identical,
      5. the consistency-tiered read API (fig_reads at smoke scale):
         SESSION reads served by followers return byte-equal scans vs the
         leader, and LEASE reads perform ZERO heartbeat-quorum rounds
         under a stable leader,
      6. chaos gate (fig_tail at smoke scale): an open-loop YCSB-A run
         through one seeded leader kill-and-recover cycle yields ZERO
         linearizability/session violations, both faults fire, and the
         recovered-phase p99 stays within 10x of the steady-state p99.
         Latency runs on the virtual clock (SimNet ticks), so the whole
         row is seed-deterministic and needs exactly one attempt,
      7. durability gate (crash-point sweep): a seeded 64-point kill -9
         sweep over the probe workload's numbered I/O ops — picks spread
         across the op range, cycling drop/torn/lost_rename — recovers
         every time with zero acked-write loss and a clean structural
         audit, and one full-cluster restart at a torn point converges
         byte-equal.  Any failure reproduces from {seed, crash_index,
         mode} alone (see repro.core.workload.run_crashpoint),
      8. self-healing gate (membership): a seeded kill-then-replace cycle
         — kill a voter hard, join a learner, auto-promote it once run
         shipping catches it up, retire the dead id — ends with zero
         history violations, a restored 3-voter quorum, byte-equal scans
         across the final voter set and nonzero learner catch-up bytes
         on the wire (Metrics.on_ship); plus a 32-point fleet kill -9
         sweep across the config-change commit window that always
         recovers to ONE committed config with no acked-write loss and
         never two leaders for one term,
      9. tracing gate (fig_trace at smoke scale): a traced chaos run
         (leader kill + lossy window) audits to ZERO causality
         violations (durable-before-ack, quorum-before-commit,
         commit-before-apply, apply-before-client-ack checked
         structurally on the span/event stream); every synced nezha put
         carries EXACTLY one value-log fsync on the leader critical
         path; and the disabled tracer is free — the untraced same-seed
         run has the identical SimNet trace and Metrics, within noise
         on wall clock,
     10. sharding gate (fig_shard at smoke scale): N=4 range shards —
         each its own Raft group over one SimNet — scale put throughput
         >= 2x over the 1-shard fabric and monotonically 1 -> 2 -> 4
         (virtual ops per simulated second, seed-deterministic), the
         cross-shard scatter-gather scan is byte-equal to an unsharded
         reference store over identical data, and a seeded kill of ONE
         shard's leader leaves zero history violations while the other
         shards keep serving.
    Returns 0 on pass, 1 on fail (wired into `make smoke` / pytest -m smoke).
    """
    from benchmarks import common
    n, vsize = 96, 1024
    wa = {}
    rows = []

    def show(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.2f},{derived}")

    print("name,us_per_call,derived")
    for engine in common.ENGINES:
        c = common.make_cluster(engine, gc_threshold=1 << 60)
        items = common.keys_values(n, vsize)
        dt, done = common.timed(c.put_many, items)
        m, eng = common.leader_metrics(c)
        wa[engine] = sum(v for k, v in m.write_bytes.items()
                         if k in common.VALUE_CATS) / max(eng.user_bytes, 1)
        show(f"smoke_put/{engine}", 1e6 * dt / done,
             f"value_writes_x={wa[engine]:.2f}")
        common.destroy(c)

    from benchmarks.fig12_batching import _make_sync_cluster
    fsyncs = {}
    for batch in (1, 32):
        c = _make_sync_cluster("nezha", batch)
        items = common.keys_values(64, vsize)
        dt, done = common.timed(c.put_many, items, window=64, batch=batch)
        fsyncs[batch] = sum(mm.fsyncs for mm in c.metrics)
        show(f"smoke_batch/nezha/b{batch}", 1e6 * dt / done,
             f"fsyncs={fsyncs[batch]}")
        common.destroy(c)

    # fig10 at smoke scale: multiple GC cycles, leveled evidence in derived
    from benchmarks import fig10_gc_impact
    gc_rows = fig10_gc_impact.run(engines=["nezha"], n=150, vsize=1024,
                                  gc_threshold=30 << 10)
    for name, us, derived in gc_rows:
        show(name.replace("fig10_gc", "smoke_gc"), us, derived)
    gc_stats = common.parse_derived(gc_rows[0][2])

    # fig_runship at smoke scale: leader-driven GC + follower adoption
    from benchmarks import fig_runship
    rs_rows = fig_runship.run(n=150, vsize=1024, gc_threshold=30 << 10)
    for name, us, derived in rs_rows:
        show(name.replace("fig_runship", "smoke_runship"), us, derived)
    rs = {name.split("/")[-1]: common.parse_derived(d)
          for name, _, d in rs_rows}

    # fig_reads at smoke scale: the consistency-tier ladder
    from benchmarks import fig_reads
    rd_rows = fig_reads.run(n_keys=120, n_gets=24, n_scans=12, sizes=(3,))
    for name, us, derived in rd_rows:
        show(name.replace("fig_reads", "smoke_reads"), us, derived)
    rd = {name.split("/", 1)[-1]: common.parse_derived(d)
          for name, _, d in rd_rows}

    # fig_tail at smoke scale: open-loop load through a leader kill, on
    # the virtual clock — seed-deterministic p99s, single attempt
    from benchmarks import fig_tail
    ch_rows = fig_tail.chaos_smoke()
    for name, us, derived in ch_rows:
        show(name, us, derived)
    ch = common.parse_derived(ch_rows[0][2])

    # crash-point durability gate: seeded 64-point kill -9 sweep + one
    # full-cluster (fleet power loss) restart at a torn point
    import tempfile
    from repro.core.faultfs import MODES
    from repro.core.workload import run_crashpoint, run_full_restart
    cp_total = cp_fail = 0
    with tempfile.TemporaryDirectory(prefix="smoke_cp_") as cpd:
        cp_ops = run_crashpoint(f"{cpd}/record", seed=23)["ops"]
        picks = sorted({(i * cp_ops) // 64 for i in range(64)})
        for i, k in enumerate(picks):
            r = run_crashpoint(f"{cpd}/p{k}", seed=23, crash_index=k,
                               mode=MODES[i % len(MODES)])
            cp_total += 1
            if not (r["crashed"] and r["recovered_ok"]):
                cp_fail += 1
        fr = run_full_restart(f"{cpd}/fleet", seed=23, crash_index=120,
                              mode="torn")
    show("smoke_crashpoints/sweep", 0,
         f"points={cp_total};failures={cp_fail};io_ops={cp_ops}")
    show("smoke_crashpoints/full_restart", 0,
         f"recovered_ok={int(fr['recovered_ok'])}"
         f";converged={int(fr['converged'])}"
         f";violations={len(fr['violations'])};audit={len(fr['audit'])}")

    # self-healing gate: seeded kill-then-replace cycle + a crash-point
    # sweep of the config-change commit window
    from repro.core.cluster import Cluster
    from repro.core.workload import (OpRecord, check_history,
                                     run_membership_crashpoint)
    with tempfile.TemporaryDirectory(prefix="smoke_heal_") as hd:
        hc = Cluster(n=3, engine="nezha", workdir=f"{hd}/c", seed=31,
                     engine_kwargs={"gc_threshold": 4096})
        hc.elect()
        heal_hist = []
        for i in range(40):
            k, v = b"hk%06d" % i, b"hv%06d" % i
            hc.put(k, v)
            heal_hist.append(OpRecord("put", k, v))
        hc.force_gc()
        hc.drain_shipping(2000)
        ship0 = sum(m.total_ship_bytes() for m in hc.metrics)
        hc.crash(1)                      # kill a voter hard
        new = hc.replace_node(1)         # learner join -> promote -> retire
        for i in range(40, 56):
            k, v = b"hk%06d" % i, b"hv%06d" % i
            hc.put(k, v)
            heal_hist.append(OpRecord("put", k, v))
        got = hc.scan(b"hk", b"hl")
        heal_hist.append(OpRecord("scan", value=got, lo=b"hk", hi=b"hl"))
        heal_viol = check_history(heal_hist)
        hl = hc.leader()
        heal_voters = sorted(hl.voters)
        for _ in range(8000):            # settle applies, then compare
            if all(hc.nodes[i].last_applied >= hl.commit_index
                   for i in heal_voters):
                break
            hc.tick()
        heal_scans = [hc.engines[i].scan(b"hk", b"hl") for i in heal_voters]
        heal_equal = all(s == heal_scans[0] for s in heal_scans[1:])
        heal_ship = sum(m.total_ship_bytes() for m in hc.metrics) - ship0
        for e in hc.engines:
            if e is not None:
                e.close()
    hm_total = hm_fail = 0
    with tempfile.TemporaryDirectory(prefix="smoke_heal_cp_") as hpd:
        hrec = run_membership_crashpoint(f"{hpd}/record", seed=31)
        mlo, mhi = hrec["member_window"]
        for k in range(32):
            r = run_membership_crashpoint(
                f"{hpd}/p{k}", seed=31,
                crash_index=mlo + (mhi - mlo) * k // 32,
                mode=("torn", "drop")[k % 2])
            hm_total += 1
            if not (r["crashed"] and r["recovered_ok"]):
                hm_fail += 1
    show("smoke_heal/replace_cycle", 0,
         f"violations={len(heal_viol)};voters={len(heal_voters)}"
         f";removed_absent={int(1 not in heal_voters)}"
         f";scan_equal={int(heal_equal)};ship_bytes={heal_ship}")
    show("smoke_heal/config_window_sweep", 0,
         f"points={hm_total};failures={hm_fail}"
         f";window={mlo}-{mhi}")

    # tracing gate: causality audit + put critical path + zero-cost-off
    from benchmarks import fig_trace
    tr_rows = fig_trace.smoke_gate()
    for name, us, derived in tr_rows:
        show(name, us, derived)
    tr = {name.split("/", 1)[-1]: common.parse_derived(d)
          for name, _, d in tr_rows}

    # sharding gate: multi-Raft scaling + scatter-gather + per-group chaos
    from benchmarks import fig_shard
    sh_rows = fig_shard.smoke_gate()
    for name, us, derived in sh_rows:
        show(name, us, derived)
    sh = {name.split("/", 1)[-1]: common.parse_derived(d)
          for name, _, d in sh_rows}

    ok = True
    if wa["nezha"] > wa["original"]:
        show("smoke/FAIL", 0, f"nezha_wa={wa['nezha']:.2f}_exceeds_"
             f"original={wa['original']:.2f}")
        ok = False
    if fsyncs[32] * 4 > fsyncs[1]:
        show("smoke/FAIL", 0, f"batch32_fsyncs={fsyncs[32]}_not_under_"
             f"quarter_of_batch1={fsyncs[1]}")
        ok = False
    if gc_stats.get("gc_cycles", 0) < 2:
        show("smoke/FAIL", 0, f"leveled_gc_never_cycled={gc_stats}")
        ok = False
    if gc_stats.get("gc_flush_last", 0) > \
            2.5 * max(gc_stats.get("gc_flush_first", 0), 1):
        show("smoke/FAIL", 0, "gc_flush_cost_grew_with_store_size="
             f"{gc_stats.get('gc_flush_first')}->"
             f"{gc_stats.get('gc_flush_last')}")
        ok = False
    if rs["shipped"].get("scan_equal") != 1:
        show("smoke/FAIL", 0, "run_shipping_follower_scan_diverged")
        ok = False
    if rs["shipped"].get("follower_gc_flush_bytes", 1) > 0:
        show("smoke/FAIL", 0, "run_shipping_follower_still_flushed_"
             f"{rs['shipped'].get('follower_gc_flush_bytes'):.0f}_bytes")
        ok = False
    if rs["shipped"].get("cluster_gc_bytes", 1) >= \
            rs["local"].get("cluster_gc_bytes", 0):
        show("smoke/FAIL", 0, "run_shipping_did_not_cut_cluster_gc_bytes="
             f"{rs['shipped'].get('cluster_gc_bytes'):.0f}_vs_local="
             f"{rs['local'].get('cluster_gc_bytes'):.0f}")
        ok = False
    if rd["lease"].get("quorum_rounds", 1) != 0:
        show("smoke/FAIL", 0, "lease_reads_paid_quorum_rounds="
             f"{rd['lease'].get('quorum_rounds', 1):.0f}"
             "_under_stable_leader")
        ok = False
    if rd["n3/session_spread"].get("scan_equal") != 1:
        show("smoke/FAIL", 0, "session_follower_scan_diverged_from_leader")
        ok = False
    if rd["n3/session_spread"].get("follower_serves", 0) <= 0:
        show("smoke/FAIL", 0, "session_reads_never_served_by_a_follower")
        ok = False
    if ch.get("violations", 1) != 0:
        show("smoke/FAIL", 0, "chaos_run_violated_consistency_x"
             f"{ch.get('violations', 1):.0f}")
        ok = False
    if ch.get("faults", 0) < 2:
        show("smoke/FAIL", 0, "chaos_schedule_did_not_fire_both_faults="
             f"{ch.get('faults', 0):.0f}")
        ok = False
    if ch.get("p99_ratio", 99) > 10:
        show("smoke/FAIL", 0, "post_failover_p99_unbounded_ratio="
             f"{ch.get('p99_ratio', 99):.2f}_steady="
             f"{ch.get('steady_p99_us', 0):.0f}us_recovered="
             f"{ch.get('recovered_p99_us', 0):.0f}us")
        ok = False
    if cp_fail:
        show("smoke/FAIL", 0, "crashpoint_sweep_lost_acked_state_at_"
             f"{cp_fail}_of_{cp_total}_points_seed23")
        ok = False
    if not fr["recovered_ok"]:
        show("smoke/FAIL", 0, "full_cluster_restart_diverged_converged="
             f"{int(fr['converged'])}_violations={len(fr['violations'])}"
             f"_audit={len(fr['audit'])}")
        ok = False
    if heal_viol or heal_voters != [0, 2, new] or not heal_equal \
            or heal_ship <= 0:
        show("smoke/FAIL", 0, "replace_cycle_violations="
             f"{len(heal_viol)}_voters={heal_voters}"
             f"_scan_equal={int(heal_equal)}_ship_bytes={heal_ship}")
        ok = False
    if hm_fail:
        show("smoke/FAIL", 0, "config_window_sweep_failed_at_"
             f"{hm_fail}_of_{hm_total}_points_seed31")
        ok = False
    if tr["chaos_audit"].get("causality_violations", 1) != 0:
        show("smoke/FAIL", 0, "traced_chaos_run_broke_causality_x"
             f"{tr['chaos_audit'].get('causality_violations', 1):.0f}")
        ok = False
    if tr["put_critical_path"].get("vlog_fsyncs_min", 0) != 1 or \
            tr["put_critical_path"].get("vlog_fsyncs_max", 0) != 1:
        show("smoke/FAIL", 0, "put_critical_path_not_one_vlog_fsync="
             f"{tr['put_critical_path'].get('vlog_fsyncs_min')}-"
             f"{tr['put_critical_path'].get('vlog_fsyncs_max')}")
        ok = False
    if tr["disabled_footprint"].get("sim_identical") != 1:
        show("smoke/FAIL", 0, "tracer_install_perturbed_the_simulation")
        ok = False
    if tr["disabled_footprint"].get("wall_ratio", 99) > 2.5:
        show("smoke/FAIL", 0, "tracing_overhead_unbounded_wall_ratio="
             f"{tr['disabled_footprint'].get('wall_ratio', 99):.2f}")
        ok = False
    if sh["shards=4"].get("scaling_x", 0) < 2.0:
        show("smoke/FAIL", 0, "sharding_4x_fabric_scaled_puts_only_"
             f"{sh['shards=4'].get('scaling_x', 0):.2f}x_over_1_shard")
        ok = False
    if not (sh["shards=1"].get("vops_s", 0)
            < sh["shards=2"].get("vops_s", 0)
            < sh["shards=4"].get("vops_s", 0)):
        show("smoke/FAIL", 0, "shard_scaling_not_monotonic_vops="
             f"{sh['shards=1'].get('vops_s', 0):.0f}->"
             f"{sh['shards=2'].get('vops_s', 0):.0f}->"
             f"{sh['shards=4'].get('vops_s', 0):.0f}")
        ok = False
    if sh["scatter_gather"].get("scan_equal") != 1:
        show("smoke/FAIL", 0, "cross_shard_scan_diverged_from_unsharded_"
             "reference")
        ok = False
    if sh["kill_group1"].get("violations", 1) != 0 or \
            sh["kill_group1"].get("faults", 0) < 2:
        show("smoke/FAIL", 0, "one_shard_leader_kill_violations="
             f"{sh['kill_group1'].get('violations', 1):.0f}_faults="
             f"{sh['kill_group1'].get('faults', 0):.0f}")
        ok = False
    if ok:
        show("smoke/PASS", 0, f"nezha_wa={wa['nezha']:.2f}"
             f";original_wa={wa['original']:.2f}"
             f";fsync_cut={fsyncs[1]}->{fsyncs[32]}"
             f";gc_cycles={gc_stats.get('gc_cycles'):.0f}"
             f";gc_flush={gc_stats.get('gc_flush_first'):.0f}->"
             f"{gc_stats.get('gc_flush_last'):.0f}"
             f";runship_cluster_gc={rs['local'].get('cluster_gc_bytes'):.0f}"
             f"->{rs['shipped'].get('cluster_gc_bytes'):.0f}"
             f";lease_rounds={rd['lease'].get('quorum_rounds', 1):.0f}"
             f";session_scaling_x="
             f"{rd['n3/session_spread'].get('scaling_x', 0):.2f}"
             f";chaos_violations={ch.get('violations', 1):.0f}"
             f";chaos_p99_ratio={ch.get('p99_ratio', 99):.2f}"
             f";crashpoints={cp_total}_all_recovered"
             f";full_restart_ok={int(fr['recovered_ok'])}"
             f";heal_voters={len(heal_voters)}"
             f";heal_ship_bytes={heal_ship}"
             f";heal_crashpoints={hm_total}_all_recovered"
             f";trace_violations="
             f"{tr['chaos_audit'].get('causality_violations'):.0f}"
             f";trace_vlog_fsyncs_per_put=1"
             f";trace_wall_ratio="
             f"{tr['disabled_footprint'].get('wall_ratio'):.2f}"
             f";shard_scaling_x={sh['shards=4'].get('scaling_x', 0):.2f}"
             f";shard_scan_equal={sh['scatter_gather'].get('scan_equal'):.0f}"
             f";shard_chaos_violations="
             f"{sh['kill_group1'].get('violations'):.0f}")
    common.write_artifact("smoke", rows)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig4..fig12,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny all-engine assertion run (CI gate)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (common, fig4_put, fig5_get, fig6_scan,
                            fig7_scan_length, fig8_ycsb, fig9_scalability,
                            fig10_gc_impact, fig11_recovery, fig12_batching,
                            fig_reads, fig_runship, fig_shard, fig_tail,
                            fig_trace, roofline)

    suites = {
        "fig4": lambda: fig4_put.run()[0],
        "fig5": fig5_get.run,
        "fig6": fig6_scan.run,
        "fig7": fig7_scan_length.run,
        "fig8": fig8_ycsb.run,
        "fig9": fig9_scalability.run,
        "fig10": fig10_gc_impact.run,
        "fig11": fig11_recovery.run,
        "fig12": fig12_batching.run,
        "fig_reads": fig_reads.run,
        "fig_runship": fig_runship.run,
        "fig_shard": fig_shard.run,
        "fig_tail": fig_tail.run,
        "fig_trace": fig_trace.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            rows = fn()
            common.emit(rows)
            path = common.write_artifact(name, rows)
            print(f"# wrote {path}", file=sys.stderr)
        except Exception as e:  # a failed suite must not hide the others
            print(f"{name}/SUITE_ERROR,0,{e!r}")
        print(f"# {name} done in {time.time() - t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
