"""Benchmark harness — one module per paper table/figure + the roofline
table from the dry-run.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  REPRO_BENCH_FULL=1 ... python -m benchmarks.run    # ~10x sizes
  python -m benchmarks.run --only fig4,roofline
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig4..fig11,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (common, fig4_put, fig5_get, fig6_scan,
                            fig7_scan_length, fig8_ycsb, fig9_scalability,
                            fig10_gc_impact, fig11_recovery, roofline)

    suites = {
        "fig4": lambda: fig4_put.run()[0],
        "fig5": fig5_get.run,
        "fig6": fig6_scan.run,
        "fig7": fig7_scan_length.run,
        "fig8": fig8_ycsb.run,
        "fig9": fig9_scalability.run,
        "fig10": fig10_gc_impact.run,
        "fig11": fig11_recovery.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            rows = fn()
            common.emit(rows)
        except Exception as e:  # a failed suite must not hide the others
            print(f"{name}/SUITE_ERROR,0,{e!r}")
        print(f"# {name} done in {time.time() - t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
