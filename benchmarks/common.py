"""Shared benchmark machinery for the paper-figure reproductions.

Scaled-down sizes (CPU container; the paper used 100GB/3-node SSD clusters):
quick mode loads a few MB per engine.  Every figure reports BOTH wall-clock
throughput/latency and the byte-accounted write/read traffic — the byte
ratios are size-invariant and carry the paper's mechanism claims.

Set REPRO_BENCH_FULL=1 for ~10x larger runs.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cluster import Cluster

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
ENGINES = ["original", "pasv", "tikv", "dwisckey", "lsmraft", "nezha_nogc",
           "nezha"]

# byte categories in which the VALUE itself hits disk (excludes 8B offsets);
# single source of truth for fig4 and the smoke gate
VALUE_CATS = {"raft_log", "wal", "flush", "compaction", "valuelog",
              "wisckey_vlog", "sst_ship"}


def make_cluster(engine: str, n: int = 3, seed: int = 7,
                 gc_threshold: int = 2 << 20) -> Cluster:
    wd = tempfile.mkdtemp(prefix=f"bench_{engine}_")
    kw = {}
    if engine == "nezha":
        kw = {"gc_threshold": gc_threshold, "gc_batch": 128}
    c = Cluster(n=n, engine=engine, workdir=wd, seed=seed, engine_kwargs=kw)
    # make Original-family engines actually flush/compact at bench scale
    for eng in c.engines:
        if hasattr(eng, "db"):
            eng.db.memtable_limit = 256 << 10
            eng.db.l0_limit = 2
    c.elect()
    return c


def keys_values(n: int, vsize: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        key = f"user{i:010d}".encode()
        val = rng.integers(0, 256, vsize, dtype=np.uint8).tobytes()
        out.append((key, val))
    return out


def zipf_indices(n_ops: int, n_keys: int, seed: int = 1, a: float = 1.2):
    rng = np.random.default_rng(seed)
    idx = rng.zipf(a, size=n_ops * 2)
    idx = idx[idx <= n_keys][:n_ops] - 1
    while len(idx) < n_ops:
        more = rng.zipf(a, size=n_ops)
        more = more[more <= n_keys] - 1
        idx = np.concatenate([idx, more])[:n_ops]
    return idx.astype(int)


def timed(fn, *args, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def leader_metrics(c: Cluster):
    ld = c.elect()
    return c.metrics[ld.nid], c.engines[ld.nid]


def emit(rows: List[Tuple[str, float, str]]):
    """CSV contract from the harness skeleton: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


def parse_derived(derived: str) -> Dict[str, object]:
    """'ops_s=997;gc_cycles=3' -> {'ops_s': 997.0, 'gc_cycles': 3.0}; non-
    numeric fields pass through as strings."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def write_artifact(fig: str, rows: List[Tuple[str, float, str]],
                   extra: Dict[str, object] = None) -> str:
    """Persist one figure's results as BENCH_<fig>.json at the repo root so
    the perf trajectory is tracked (and diffed) across PRs."""
    import json
    path = os.path.join(os.path.dirname(__file__), "..", f"BENCH_{fig}.json")
    doc = {"fig": fig, "full": FULL,
           "rows": [{"name": n, "us_per_call": round(us, 2),
                     "derived": parse_derived(d)} for n, us, d in rows]}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return os.path.abspath(path)


def destroy(c: Cluster):
    c.destroy()
