"""Fig. 11 — node recovery time by GC state (Pre/During/Post) vs Original.

Paper claim: ~33-35% faster recovery for Nezha in all states: the state
machine replays lightweight offsets, and post-GC the snapshot truncates the
log tail."""
from __future__ import annotations

import time

from benchmarks import common

VSIZE = 4096
N = 400 if not common.FULL else 1500


def _recover_time(engine: str, stage: str) -> float:
    gc_threshold = (N * VSIZE) // 2 if stage != "pre" else 1 << 60
    c = common.make_cluster(engine, gc_threshold=gc_threshold)
    c.put_many(common.keys_values(N, VSIZE))
    eng = c.engines[c.elect().nid]
    if engine == "nezha":
        if stage == "during":
            if not eng.gc_started or eng.gc_completed:
                eng.start_gc()
            eng.gc_step(64)           # partial progress
        elif stage == "post":
            if not (eng.gc_started and not eng.gc_completed):
                if eng.gc_completed and not eng.leveled.runs:
                    eng.start_gc()
            eng.run_gc_to_completion()
    victim = c.elect().nid
    c.crash(victim)
    dt = c.restart(victim)
    common.destroy(c)
    return dt


def run():
    rows = []
    base = _recover_time("original", "pre")
    rows.append(("fig11_recovery/original", base * 1e6, "baseline"))
    for stage in ["pre", "during", "post"]:
        dt = _recover_time("nezha", stage)
        rows.append((f"fig11_recovery/nezha_{stage}", dt * 1e6,
                     f"vs_original={dt / base:.2f}x"))
    return rows


if __name__ == "__main__":
    common.emit(run())
