"""Fig. RS — run-shipping replication: cluster-wide GC write amplification
and apply throughput, local-GC baseline vs leader-driven GC with follower
run adoption (3-node cluster, several GC cycles + level merges).

Claim under measurement: with run shipping on, follower per-cycle GC flush
bytes drop to ~0 and cluster-wide GC rewrite work falls to the leader's
share (~1/N of the local-GC baseline), while follower stores stay
byte-for-byte scan-equivalent to the leader.  The price is explicit and
accounted: run/snapshot bytes on the wire (Metrics.on_ship) and the
followers' one-time run installs ('run_adopt')."""
from __future__ import annotations

import tempfile

from benchmarks import common
from repro.core.cluster import Cluster

N = 1200 if common.FULL else 420
VSIZE = 1024


def run(engines=None, n=None, vsize=None, gc_threshold=None, seed=11):
    n = n or N
    vsize = vsize or VSIZE
    gc_threshold = gc_threshold or max((n // 6) * vsize, 16 << 10)
    rows = []
    for mode in ("local", "shipped"):
        wd = tempfile.mkdtemp(prefix=f"runship_{mode}_")
        c = Cluster(n=3, engine="nezha", workdir=wd, seed=seed,
                    engine_kwargs={"gc_threshold": gc_threshold,
                                   "gc_batch": 128, "level_fanout": 2,
                                   "run_shipping": mode == "shipped"})
        # counters are engine-lifetime cumulative: baseline right after
        # construction so the derived numbers are THIS run's movement
        base = [m.snapshot() for m in c.metrics]
        items = common.keys_values(n, vsize)
        dt, done = common.timed(c.put_many, items)
        ld = c.elect()
        c.engines[ld.nid].run_gc_to_completion()
        if mode == "shipped":
            c.drain_shipping()
        else:
            for _ in range(2000):
                c.tick()
                if all(c.nodes[p].last_applied >= ld.commit_index
                       for p in ld.peers):
                    break
        ld = c.elect()
        le = c.engines[ld.nid]
        fids = [i for i in range(3) if i != ld.nid]
        lscan = le.scan(b"", b"\xff" * 11)
        equal = all(c.engines[f].scan(b"", b"\xff" * 11) == lscan
                    for f in fids)
        deltas = [m.delta(s) for m, s in zip(c.metrics, base)]
        gc_cats = ("gc_sorted", "gc_level_merge")
        cluster_gc = sum(d["write_bytes"].get(cat, 0)
                         for d in deltas for cat in gc_cats)
        fol_flush = sum(deltas[f]["write_bytes"].get("gc_sorted", 0)
                        for f in fids)
        fol_merge = sum(deltas[f]["write_bytes"].get("gc_level_merge", 0)
                        for f in fids)
        adopt = sum(deltas[f]["write_bytes"].get("run_adopt", 0)
                    for f in fids)
        ship = sum(sum(d["ship_bytes"].values()) for d in deltas)
        user = max(le.user_bytes, 1)
        derived = (f"ops_s={done / dt:.0f}"
                   f";cluster_gc_bytes={cluster_gc}"
                   f";cluster_gc_wa={cluster_gc / (3 * user):.3f}"
                   f";follower_gc_flush_bytes={fol_flush}"
                   f";follower_gc_merge_bytes={fol_merge}"
                   f";adopt_bytes={adopt};ship_bytes={ship}"
                   f";gc_cycles={le.gc_count};scan_equal={int(equal)}")
        rows.append((f"fig_runship/{mode}", 1e6 * dt / done, derived))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
