"""Fig. 4 — put throughput/latency vs value size, all engines.

Paper claim: Nezha ≈ Nezha-NoGC >> Dwisckey > LSM-Raft/PASV > Original/TiKV,
driven by value-write count (>=3x -> 1x).  We report ops/s, us/op, and the
byte-accounted value-write amplification that explains the ordering.
"""
from __future__ import annotations

from benchmarks import common

VALUE_SIZES = [1024, 4096, 16384] + ([65536] if common.FULL else [])
N_BYTES_TARGET = (32 << 20) if common.FULL else (3 << 20)

VALUE_CATS = common.VALUE_CATS


def run(engines=None):
    rows = []
    detail = {}
    for engine in engines or common.ENGINES:
        for vsize in VALUE_SIZES:
            n = max(N_BYTES_TARGET // vsize, 64)
            # NOTE: this container has ONE core, so Nezha's background GC
            # would serialize into the measured write path (the paper's
            # 12-core nodes run it truly async).  fig4 therefore measures
            # the write path with GC deferred; fig10 measures the inline-GC
            # timeline explicitly.
            c = common.make_cluster(engine, gc_threshold=1 << 60)
            items = common.keys_values(n, vsize)
            dt, done = common.timed(c.put_many, items)
            m, eng = common.leader_metrics(c)
            wa = sum(v for k, v in m.write_bytes.items()
                     if k in VALUE_CATS) / max(eng.user_bytes, 1)
            ops = done / dt
            note = ";gc=deferred_async" if engine == "nezha" else ""
            rows.append((f"fig4_put/{engine}/v{vsize}", 1e6 * dt / done,
                         f"ops_s={ops:.0f};value_writes_x={wa:.2f}{note}"))
            detail[(engine, vsize)] = (ops, wa)
            common.destroy(c)
    return rows, detail


if __name__ == "__main__":
    common.emit(run()[0])
