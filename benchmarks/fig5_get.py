"""Fig. 5 — point-query throughput/latency vs value size.

Paper claim: Nezha-NoGC < Original (offset indirection penalty) but
Nezha > Original (hash-indexed sorted file)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

VALUE_SIZES = [1024, 4096, 16384]
N_BYTES_TARGET = (16 << 20) if common.FULL else (3 << 20)
N_GETS = 2000 if common.FULL else 400


def run(engines=None):
    rows = []
    for engine in engines or common.ENGINES:
        for vsize in VALUE_SIZES:
            n = max(N_BYTES_TARGET // vsize, 64)
            c = common.make_cluster(engine,
                                    gc_threshold=max(N_BYTES_TARGET // 3,
                                                     1 << 20))
            c.put_many(common.keys_values(n, vsize))
            if engine == "nezha":        # let GC finish reorganizing
                c.engines[c.elect().nid].run_gc_to_completion()
            eng = c.engines[c.elect().nid]
            idx = common.zipf_indices(N_GETS, n)
            dt, _ = common.timed(
                lambda: [eng.get(f"user{i:010d}".encode()) for i in idx])
            rows.append((f"fig5_get/{engine}/v{vsize}", 1e6 * dt / N_GETS,
                         f"ops_s={N_GETS / dt:.0f}"))
            common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
