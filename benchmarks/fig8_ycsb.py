"""Fig. 8 / Table II — YCSB workloads Load + A-F (Zipf key access)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

VSIZE = 4096
N_KEYS = 1500 if common.FULL else 500
N_OPS = 2000 if common.FULL else 400

WORKLOADS = {
    "load": dict(write=1.0, scan=0.0, rmw=False, insert=True),
    "A": dict(write=0.5, scan=0.0, rmw=False, insert=False),
    "B": dict(write=0.05, scan=0.0, rmw=False, insert=False),
    "C": dict(write=0.0, scan=0.0, rmw=False, insert=False),
    "D": dict(write=0.05, scan=0.0, rmw=False, insert=True),
    "E": dict(write=0.05, scan=0.95, rmw=False, insert=True),
    "F": dict(write=0.5, scan=0.0, rmw=True, insert=False),
}


def run(engines=None, workloads=None):
    rows = []
    for engine in engines or common.ENGINES:
        c = common.make_cluster(engine, gc_threshold=1 << 20)
        c.put_many(common.keys_values(N_KEYS, VSIZE))
        if engine == "nezha":
            c.engines[c.elect().nid].run_gc_to_completion()
        eng = c.engines[c.elect().nid]
        rng = np.random.default_rng(9)
        val = rng.integers(0, 256, VSIZE, dtype=np.uint8).tobytes()
        for wname in (workloads or WORKLOADS):
            w = WORKLOADS[wname]
            zipf = common.zipf_indices(N_OPS, N_KEYS, seed=11)
            inserted = N_KEYS

            def ops():
                nonlocal inserted
                for j in range(N_OPS):
                    i = int(zipf[j])
                    r = rng.random()
                    if wname == "load" or (w["insert"] and r < w["write"]):
                        inserted += 1
                        c.put(f"user{inserted:010d}".encode(), val)
                    elif r < w["write"]:
                        if w["rmw"]:
                            eng.get(f"user{i:010d}".encode())
                        c.put(f"user{i:010d}".encode(), val)
                    elif r < w["write"] + w["scan"]:
                        lo = min(i, N_KEYS - 25)
                        eng.scan(f"user{lo:010d}".encode(),
                                 f"user{lo + 24:010d}".encode())
                    else:
                        eng.get(f"user{i:010d}".encode())

            dt, _ = common.timed(ops)
            rows.append((f"fig8_ycsb/{engine}/{wname}", 1e6 * dt / N_OPS,
                         f"ops_s={N_OPS / dt:.0f}"))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
