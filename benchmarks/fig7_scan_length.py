"""Fig. 7 — range-query performance vs scan cardinality (10..1000 pairs)."""
from __future__ import annotations

from benchmarks import common

SCAN_LENS = [10, 100, 1000] if common.FULL else [10, 50, 200]
VSIZE = 4096
N_KEYS = 2000 if common.FULL else 800
N_SCANS = 20


def run(engines=None):
    rows = []
    for engine in engines or common.ENGINES:
        c = common.make_cluster(engine, gc_threshold=1 << 20)
        c.put_many(common.keys_values(N_KEYS, VSIZE))
        if engine == "nezha":
            c.engines[c.elect().nid].run_gc_to_completion()
        eng = c.engines[c.elect().nid]
        for slen in SCAN_LENS:
            def scans():
                for s in range(N_SCANS):
                    start = (s * 101) % (N_KEYS - slen)
                    out = eng.scan(f"user{start:010d}".encode(),
                                   f"user{start + slen - 1:010d}".encode())
                    assert len(out) == slen

            dt, _ = common.timed(scans)
            rows.append((f"fig7_scanlen/{engine}/len{slen}",
                         1e6 * dt / N_SCANS,
                         f"scans_s={N_SCANS / dt:.1f}"))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
