"""Shard-scaling: put throughput vs number of Raft groups.

The single-group baseline serializes every put through one leader — one
log, one fsync pipeline, one replication window — so adding replicas
does NOT add write throughput (the n=3 vs n=5 baseline rows are flat;
a wider quorum is, if anything, slower).  The sharded fabric
(repro/core/shards.py) splits the keyspace into N independent Raft
groups over one SimNet and the sharded client keeps every group's
in-flight window full in the SAME tick loop, so N commit pipelines
(append + fsync + replication round) overlap in virtual time and put
throughput scales with N.

Measurement follows the PR 7 convention: the gate metric is VIRTUAL
throughput — ops per simulated second (SimNet ticks x tick_us) — a pure
function of {seed, schedule} that container noise cannot move; wall
clock is reported alongside for information only (one Python process
simulates all shards, so wall time grows with total work regardless of
scaling).

Also here: the cross-shard scatter-gather scan check (stitched result
byte-equal to an unsharded reference store over identical data) and the
per-group chaos leg (one shard's leader killed mid-workload, zero
checked-history violations).  smoke_gate() is CI gate #10.
"""
from __future__ import annotations

import tempfile
import time

from benchmarks import common
from repro.core.client import LINEARIZABLE
from repro.core.cluster import Cluster
from repro.core.shards import ShardMap, ShardedCluster
from repro.core.workload import (ChaosSchedule, Tenant, WorkloadSpec,
                                 run_workload, _key)

N_ITEMS = 6000 if common.FULL else 1600
VSIZE = 128
WINDOW = 64
TICK_US = 50.0      # same virtual-time scale the workload harness uses
SHARD_COUNTS = (1, 2, 4)


def _sharded(n_shards: int, keys, seed: int = 7,
             n: int = 3) -> ShardedCluster:
    wd = tempfile.mkdtemp(prefix=f"bench_shard{n_shards}_")
    sc = ShardedCluster(n_shards=n_shards, n=n, engine="nezha",
                        workdir=wd, seed=seed,
                        shard_map=ShardMap.from_keys(keys, n_shards))
    sc.elect()
    return sc


def _vthroughput(cluster, items) -> tuple:
    """(virtual ops/s, wall ops/s, done) for one put_many over items."""
    t0 = cluster.net.time
    w0 = time.perf_counter()
    done = cluster.put_many(items, window=WINDOW)
    wall = time.perf_counter() - w0
    dticks = max(cluster.net.time - t0, 1)
    vops = done / (dticks * TICK_US * 1e-6)
    return vops, done / max(wall, 1e-9), done


def scaling(n_items: int = N_ITEMS) -> list:
    """Put throughput at 1 / 2 / 4 shards over identical items."""
    items = common.keys_values(n_items, VSIZE)
    keys = [k for k, _ in items]
    rows = []
    base_vops = None
    for s in SHARD_COUNTS:
        sc = _sharded(s, keys)
        vops, wops, done = _vthroughput(sc, items)
        if base_vops is None:
            base_vops = vops
        rows.append((f"fig_shard_puts/shards={s}", 1e6 / max(wops, 1e-9),
                     f"items={done};vops_s={vops:.0f}"
                     f";wall_ops_s={wops:.0f}"
                     f";scaling_x={vops / base_vops:.2f}"))
        sc.destroy()
    return rows


def baseline_flat(n_items: int = N_ITEMS) -> list:
    """Control: a single Raft group does NOT scale writes with replicas."""
    items = common.keys_values(n_items, VSIZE)
    rows = []
    base_vops = None
    for n in (3, 5):
        wd = tempfile.mkdtemp(prefix=f"bench_shard_base{n}_")
        c = Cluster(n=n, engine="nezha", workdir=wd, seed=7)
        c.elect()
        vops, wops, done = _vthroughput(c, items)
        if base_vops is None:
            base_vops = vops
        rows.append((f"fig_shard_baseline/n={n}",
                     1e6 / max(wops, 1e-9),
                     f"items={done};vops_s={vops:.0f}"
                     f";scaling_x={vops / base_vops:.2f}"))
        common.destroy(c)
    return rows


def scan_equality(n_items: int = 600) -> tuple:
    """Cross-shard scatter-gather scan == unsharded reference, bytewise."""
    items = common.keys_values(n_items, VSIZE)
    keys = [k for k, _ in items]
    sc = _sharded(4, keys, seed=9)
    sc.put_many(items, window=WINDOW)
    wd = tempfile.mkdtemp(prefix="bench_shard_ref_")
    ref = Cluster(n=3, engine="nezha", workdir=wd, seed=9)
    ref.elect()
    ref.put_many(items, window=WINDOW)
    got = sc.scan(keys[0], keys[-1], LINEARIZABLE)
    exp = ref.scan(keys[0], keys[-1], LINEARIZABLE)
    equal = int(got == exp and len(got) == n_items)
    touched = len(list(sc.shard_map.shards_for_range(keys[0], keys[-1])))
    sc.destroy()
    common.destroy(ref)
    return ("fig_shard_scan/scatter_gather", 0.0,
            f"items={n_items};shards_touched={touched}"
            f";scan_equal={equal}")


def chaos_one_shard(n_ops: int = 160) -> tuple:
    """Kill ONE shard's leader under the checked workload: the other
    shards keep serving and the history audits clean."""
    n_keys = max(n_ops, 120)
    keys = [_key(i) for i in range(n_keys)]
    wd = tempfile.mkdtemp(prefix="bench_shard_chaos_")
    sc = ShardedCluster(n_shards=4, n=3, engine="nezha", workdir=wd,
                        seed=13, shard_map=ShardMap.from_keys(keys, 4))
    sc.elect()
    spec = WorkloadSpec(n_ops=n_ops, n_keys=n_keys, vsize=128, seed=3,
                        virtual_time=True, tick_us=TICK_US,
                        tenants=(Tenant("lin", 1.0, "A", LINEARIZABLE),))
    sched = ChaosSchedule.kill_and_recover(at=0.3, restart_at=0.7,
                                           seed=3, group=1)
    rep = run_workload(sc, spec, chaos=sched)
    groups_hit = sorted({e.get("group") for e in rep.timeline})
    sc.destroy()
    return ("fig_shard_chaos/kill_group1", 0.0,
            f"ops={n_ops};violations={len(rep.violations)}"
            f";faults={len(rep.timeline)}"
            f";groups_hit={'|'.join(map(str, groups_hit))}")


def smoke_gate() -> list:
    """CI gate #10 (benchmarks/run.py smoke()): N=4 shards scale puts
    >= 2x over 1 shard (virtual throughput), the cross-shard scan is
    byte-equal to the unsharded reference, and one shard's leader kill
    leaves zero violations."""
    rows = scaling(n_items=800)
    rows.append(scan_equality(n_items=400))
    rows.append(chaos_one_shard(n_ops=120))
    return [(name.replace("fig_shard", "smoke_shard"), us, derived)
            for name, us, derived in rows]


def run() -> list:
    rows = scaling()
    rows += baseline_flat()
    rows.append(scan_equality())
    rows.append(chaos_one_shard())
    return rows


if __name__ == "__main__":
    rows = run()
    common.emit(rows)
    path = common.write_artifact("fig_shard", rows)
    import sys
    print(f"# wrote {path}", file=sys.stderr)
