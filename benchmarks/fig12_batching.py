"""Fig. 12 — group-commit batching sweep: batch size vs put throughput and
fsync count, plus a get-heavy phase showing the read-path caching win.

Mechanism under test (the batched I/O pipeline):
  * the leader persists a whole client batch with ONE buffered write and
    ONE fsync per store (ValueLog.append_batch + commit_window),
  * followers receive up to `max_batch` entries per AppendEntries and ack
    the batch with one fsync,
  * point gets consult per-SSTable bloom filters (zero bytes on a skip)
    and the shared BlockCache (zero bytes on a hit).

Expected: batch=64 delivers >= 3x the put ops/s of batch=1 with <= 1/8 the
fsyncs, for every engine; byte-accounted write amplification is UNCHANGED
by batching (the paper's relative story is preserved, just faster).
"""
from __future__ import annotations

import tempfile

from benchmarks import common
from repro.core.cluster import Cluster

BATCHES = [1, 8, 64]
VSIZE = 1024
N_ITEMS = 1024 if common.FULL else 256
N_GETS = 2000 if common.FULL else 400


def _make_sync_cluster(engine: str, batch: int, seed: int = 7) -> Cluster:
    wd = tempfile.mkdtemp(prefix=f"bench12_{engine}_b{batch}_")
    kw = {}
    if engine == "nezha":
        kw = {"gc_threshold": 1 << 60, "gc_batch": 128}  # GC deferred (fig4)
    c = Cluster(n=3, engine=engine, workdir=wd, seed=seed, sync=True,
                max_batch=batch, engine_kwargs=kw)
    for eng in c.engines:
        if hasattr(eng, "db"):
            eng.db.memtable_limit = 256 << 10
            eng.db.l0_limit = 2
    c.elect()
    return c


def run(engines=None):
    rows = []
    for engine in engines or common.ENGINES:
        base = {}
        for batch in BATCHES:
            c = _make_sync_cluster(engine, batch)
            items = common.keys_values(N_ITEMS, VSIZE)
            dt, done = common.timed(c.put_many, items, window=128,
                                    batch=batch)
            m, eng = common.leader_metrics(c)
            fsyncs = sum(mm.fsyncs for mm in c.metrics)
            ops = done / dt
            if batch == BATCHES[0]:
                base = {"ops": ops, "fsyncs": fsyncs}
            rows.append((f"fig12_batching/{engine}/b{batch}",
                         1e6 * dt / done,
                         f"ops_s={ops:.0f};fsyncs={fsyncs}"
                         f";speedup_x={ops / base['ops']:.2f}"
                         f";fsync_ratio={fsyncs / max(base['fsyncs'], 1):.4f}"))
            if batch == BATCHES[-1]:
                # get-heavy phase: bloom skips + block-cache hits cut bytes
                ld = c.elect()
                m = c.metrics[ld.nid]
                m.read_bytes.clear()
                m.read_ops.clear()
                # half hot existing keys (cache), half absent keys (bloom)
                idx = common.zipf_indices(N_GETS // 2, N_ITEMS)
                keys = [f"user{i:010d}".encode() for i in idx] + \
                    [f"zzzz{i:08d}".encode() for i in range(N_GETS // 2)]
                gdt, _ = common.timed(lambda: [eng.get(k) for k in keys])
                pr = m.read_bytes.get("sst_point", 0) + \
                    m.read_bytes.get("sorted_point", 0) + \
                    m.read_bytes.get("valuelog", 0)
                hits = sum(m.cache_hits.values())
                rows.append((f"fig12_getheavy/{engine}",
                             1e6 * gdt / N_GETS,
                             f"point_read_bytes={pr};cache_hits={hits}"
                             f";bloom_skips={m.bloom_skips}"))
            common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
