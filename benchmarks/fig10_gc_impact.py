"""Fig. 10 — impact of GC on the write path: cumulative throughput timeline
while loading enough data to trigger two GC cycles.

Paper claim: Nezha ~= Nezha-NoGC throughout (GC runs on the separate Active
module; writes atomically switch to New Storage), both >> Original."""
from __future__ import annotations

import time

from benchmarks import common

VSIZE = 4096
N = 1200 if common.FULL else 600
GC_THRESHOLD = (N // 3) * VSIZE  # two GC triggers over the run
WINDOW = 50


def run(engines=None):
    rows = []
    for engine in engines or ["original", "nezha_nogc", "nezha"]:
        c = common.make_cluster(engine, gc_threshold=GC_THRESHOLD)
        items = common.keys_values(N, VSIZE)
        stamps = []
        t0 = time.perf_counter()
        for i in range(0, N, WINDOW):
            c.put_many(items[i:i + WINDOW])
            stamps.append(time.perf_counter() - t0)
        eng = c.engines[c.elect().nid]
        gcs = getattr(eng, "gc_count", 0)
        # throughput in each window; report min/mean ratio (GC dips)
        import numpy as np
        widths = np.diff([0.0] + stamps)
        thr = WINDOW / widths
        rows.append((f"fig10_gc/{engine}", 1e6 * stamps[-1] / N,
                     f"ops_s={N / stamps[-1]:.0f};min_window_ops_s="
                     f"{thr.min():.0f};gc_cycles={gcs}"))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
