"""Fig. 10 — impact of GC on the write path: cumulative throughput timeline
while loading enough data to trigger two GC cycles.

Paper claim: Nezha ~= Nezha-NoGC throughout (GC runs on the separate Active
module; writes atomically switch to New Storage), both >> Original."""
from __future__ import annotations

import time

from benchmarks import common

VSIZE = 4096
N = 1200 if common.FULL else 600
WINDOW = 50


def run(engines=None, n=None, vsize=None, gc_threshold=None):
    n = n or N
    vsize = vsize or VSIZE
    gc_threshold = gc_threshold or (n // 3) * vsize
    rows = []
    for engine in engines or ["original", "nezha_nogc", "nezha"]:
        c = common.make_cluster(engine, gc_threshold=gc_threshold)
        items = common.keys_values(n, vsize)
        stamps = []
        t0 = time.perf_counter()
        for i in range(0, n, WINDOW):
            c.put_many(items[i:i + WINDOW])
            stamps.append(time.perf_counter() - t0)
        ld = c.elect()
        eng = c.engines[ld.nid]
        gcs = getattr(eng, "gc_count", 0)
        # throughput in each window; report min/mean ratio (GC dips)
        import numpy as np
        widths = np.diff([0.0] + stamps)
        thr = WINDOW / widths
        derived = (f"ops_s={n / stamps[-1]:.0f};min_window_ops_s="
                   f"{thr.min():.0f};gc_cycles={gcs}")
        if engine == "nezha":
            # leveled-GC evidence: flat per-cycle flush cost + total GC
            # write amplification (monolithic GC grew per cycle)
            m = c.metrics[ld.nid]
            flushes = m.gc_flush_bytes_per_cycle()
            if flushes:
                derived += (f";gc_flush_first={flushes[0]}"
                            f";gc_flush_last={flushes[-1]}")
            derived += (f";gc_bytes={m.gc_total_bytes()}"
                        f";gc_wa={m.gc_write_amplification(eng.user_bytes):.2f}"
                        f";runs={len(eng.leveled.runs)}"
                        f";levels={len(eng.leveled.level_shape())}")
        rows.append((f"fig10_gc/{engine}", 1e6 * stamps[-1] / n, derived))
        common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
