"""Fig. 6 — range-query throughput vs value size.

Paper claim: Nezha-NoGC is much worse than Original (scattered ValueLog =>
random reads) while Nezha beats Original (sorted file: ONE seek + sequential
read).  The read-op accounting proves the mechanism: sorted_range read count
== 1 per scan."""
from __future__ import annotations

from benchmarks import common

VALUE_SIZES = [1024, 4096, 16384]
N_BYTES_TARGET = (16 << 20) if common.FULL else (3 << 20)
N_SCANS = 60 if common.FULL else 25
SCAN_LEN = 50


def run(engines=None):
    rows = []
    for engine in engines or common.ENGINES:
        for vsize in VALUE_SIZES:
            n = max(N_BYTES_TARGET // vsize, 200)
            c = common.make_cluster(engine,
                                    gc_threshold=max(N_BYTES_TARGET // 3,
                                                     1 << 20))
            c.put_many(common.keys_values(n, vsize))
            if engine == "nezha":
                c.engines[c.elect().nid].run_gc_to_completion()
            m, eng = common.leader_metrics(c)
            m.read_ops.clear()

            def scans():
                for s in range(N_SCANS):
                    start = (s * 37) % (n - SCAN_LEN)
                    lo = f"user{start:010d}".encode()
                    hi = f"user{start + SCAN_LEN - 1:010d}".encode()
                    out = eng.scan(lo, hi)
                    assert len(out) == SCAN_LEN, (engine, len(out))

            dt, _ = common.timed(scans)
            seq_reads = m.read_ops.get("sorted_range", 0)
            rand_reads = m.read_ops.get("valuelog", 0) + \
                m.read_ops.get("wisckey_vlog", 0) + \
                m.read_ops.get("sst_range", 0)
            rows.append((
                f"fig6_scan/{engine}/v{vsize}", 1e6 * dt / N_SCANS,
                f"scans_s={N_SCANS / dt:.1f};seq_reads={seq_reads};"
                f"random_reads={rand_reads}"))
            common.destroy(c)
    return rows


if __name__ == "__main__":
    common.emit(run())
