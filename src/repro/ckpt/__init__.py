from repro.ckpt.nezha_store import NezhaCheckpointStore  # noqa: F401
