"""KV-separated checkpointing — the paper's technique as training infra.

Mapping (DESIGN.md §2):
  value  = raw tensor-shard bytes      -> appended ONCE to a host-local
                                          ValueLog (no staging copy, no WAL)
  key    = (step, pytree path, shard)  -> manifest entry: (gen, offset, len,
                                          dtype, shape)
  consensus = the manifest (a few KB)  -> committed through the Raft cluster
                                          (core.Cluster w/ NezhaEngine); the
                                          tensor bytes NEVER cross consensus
  GC     = compaction of superseded checkpoints into a NAME-SORTED file
           (sequential restore = the paper's sorted-ValueLog scan win), with
           new saves redirected to a fresh ValueLog meanwhile (three-phase)

A checkpoint is durable when its manifest commits; a crash mid-save leaves a
dangling (unreferenced) tail in the ValueLog that the next GC collects —
write amplification for checkpointing is exactly 1.0 + GC.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.metrics import Metrics
from repro.utils import path_str

PyTree = Any


class _Vlog:
    def __init__(self, path: str, metrics: Metrics, category: str):
        self.path = path
        self.metrics = metrics
        self.category = category
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")
        self.size = self._f.tell()

    def append(self, data: bytes) -> int:
        off = self.size
        self._f.write(data)
        self.size += len(data)
        self.metrics.on_write(self.category, len(data))
        return off

    def read(self, off: int, length: int) -> bytes:
        self._f.flush()
        with open(self.path, "rb") as f:
            f.seek(off)
            data = f.read(length)
        self.metrics.on_read(self.category, length)
        return data

    def close(self):
        self._f.close()

    def delete(self):
        self.close()
        if os.path.exists(self.path):
            os.remove(self.path)


class NezhaCheckpointStore:
    def __init__(self, dirpath: str, metrics: Optional[Metrics] = None, *,
                 cluster=None, keep: int = 2,
                 gc_threshold_bytes: int = 256 << 20):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics or Metrics()
        self.cluster = cluster            # optional repro.core Cluster
        self.keep = keep
        self.gc_threshold = gc_threshold_bytes
        self.gen = 0
        self.vlog = _Vlog(os.path.join(dirpath, f"ckpt_{self.gen:04d}.vlog"),
                          self.metrics, "ckpt_valuelog")
        self.manifests: Dict[int, dict] = {}       # step -> manifest
        self._manifest_dir = os.path.join(dirpath, "manifests")
        os.makedirs(self._manifest_dir, exist_ok=True)
        self._load_manifests()

    # -------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, host_id: int = 0) -> dict:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        entries = {}
        for path, leaf in flat:
            arr = np.asarray(leaf)
            data = arr.tobytes()
            off = self.vlog.append(data)            # the ONE tensor write
            entries[path_str(path)] = {
                "gen": self.gen, "offset": off, "length": len(data),
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "host": host_id,
            }
        manifest = {"step": step, "entries": entries,
                    "vlog_gen": self.gen}
        self._commit_manifest(step, manifest)
        self.manifests[step] = manifest
        self._maybe_gc()
        return manifest

    def _commit_manifest(self, step: int, manifest: dict):
        blob = json.dumps(manifest).encode()
        if self.cluster is not None:
            # lightweight metadata through consensus (KVS-Raft style)
            self.cluster.put(f"ckpt_manifest/{step:012d}".encode(), blob)
        path = os.path.join(self._manifest_dir, f"{step:012d}.json")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)                       # atomic commit point
        self.metrics.on_write("ckpt_manifest", len(blob))

    def _load_manifests(self):
        for fn in sorted(os.listdir(self._manifest_dir)):
            if fn.endswith(".json"):
                with open(os.path.join(self._manifest_dir, fn)) as f:
                    m = json.load(f)
                self.manifests[m["step"]] = m

    # ------------------------------------------------------------ restore
    def latest_step(self) -> Optional[int]:
        if self.cluster is not None:
            sc = self.cluster.scan(b"ckpt_manifest/", b"ckpt_manifest/~")
            if sc:
                return json.loads(sc[-1][1])["step"]
        return max(self.manifests) if self.manifests else None

    def restore(self, tree_like: PyTree, step: Optional[int] = None) -> \
            Tuple[PyTree, int]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint"
        manifest = self.manifests.get(step)
        if manifest is None and self.cluster is not None:
            blob = self.cluster.get(f"ckpt_manifest/{step:012d}".encode())
            manifest = json.loads(blob)
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for path, leaf in flat:
            e = manifest["entries"][path_str(path)]
            data = self._read_entry(e)
            arr = np.frombuffer(data, dtype=e["dtype"]).reshape(e["shape"])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def _read_entry(self, e: dict) -> bytes:
        gen = e["gen"]
        if gen == self.gen:
            return self.vlog.read(e["offset"], e["length"])
        path = os.path.join(self.dir, f"ckpt_{gen:04d}.vlog")
        with open(path, "rb") as f:
            f.seek(e["offset"])
            data = f.read(e["length"])
        self.metrics.on_read("ckpt_valuelog", e["length"])
        return data

    # ----------------------------------------------------------------- GC
    def _maybe_gc(self):
        if self.vlog.size >= self.gc_threshold:
            self.gc()

    def gc(self):
        """Compact live checkpoints into a fresh, NAME-SORTED ValueLog.
        Sorted layout => restore() reads sequentially (paper's scan win)."""
        live_steps = sorted(self.manifests)[-self.keep:]
        old_gens = {self.manifests[s]["vlog_gen"] for s in live_steps} | \
            {self.gen}
        self.gen += 1
        new_vlog = _Vlog(os.path.join(self.dir, f"ckpt_{self.gen:04d}.vlog"),
                         self.metrics, "ckpt_gc")
        for s in live_steps:
            man = self.manifests[s]
            for name in sorted(man["entries"]):     # key-sorted layout
                e = man["entries"][name]
                data = self._read_entry(e)
                e["offset"] = new_vlog.append(data)
                e["gen"] = self.gen
            man["vlog_gen"] = self.gen
            self._commit_manifest(s, man)
        # drop superseded manifests + old logs (cleanup phase)
        for s in list(self.manifests):
            if s not in live_steps:
                del self.manifests[s]
                p = os.path.join(self._manifest_dir, f"{s:012d}.json")
                if os.path.exists(p):
                    os.remove(p)
        self.vlog.close()
        for g in old_gens:
            p = os.path.join(self.dir, f"ckpt_{g:04d}.vlog")
            if os.path.exists(p) and g != self.gen:
                os.remove(p)
        self.vlog = new_vlog

    def close(self):
        self.vlog.close()
