"""Pure-jnp oracle for paged decode attention (block-table gather)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, pool_k, pool_v, table, length):
    """q: (B, nh, hd); pool_k/v: (B, nblk, bs, nkv, hd); table: (B, nblk)
    int32 (logical block -> physical block); length: scalar or (B,) number of
    valid tokens.  Returns (B, nh, hd)."""
    B, nh, hd = q.shape
    nblk, bs, nkv = pool_k.shape[1], pool_k.shape[2], pool_k.shape[3]
    rep = nh // nkv
    tbl = table[..., None, None, None]
    k = jnp.take_along_axis(pool_k, tbl, axis=1).reshape(B, nblk * bs, nkv, hd)
    v = jnp.take_along_axis(pool_v, tbl, axis=1).reshape(B, nblk * bs, nkv, hd)
    qf = q.astype(jnp.float32).reshape(B, nkv, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qf, k.astype(jnp.float32)) * hd ** -0.5
    length = jnp.broadcast_to(jnp.asarray(length), (B,))
    mask = jnp.arange(nblk * bs)[None] < length[:, None]      # (B, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return o.reshape(B, nh, hd).astype(q.dtype)
