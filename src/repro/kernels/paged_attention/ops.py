"""jit'd dispatch for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_decode_attention(q, pool_k, pool_v, table, length, *,
                           backend: str = None):
    backend = backend or default_backend()
    if backend == "reference":
        return paged_decode_attention_ref(q, pool_k, pool_v, table, length)
    return paged_decode_attention_pallas(
        q, pool_k, pool_v, table, length,
        interpret=(backend == "pallas_interpret"))
