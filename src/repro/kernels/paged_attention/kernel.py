"""Paged decode attention — Pallas TPU kernel with block-table indirection.

This is the paper's KV-separation read path on TPU (DESIGN.md §2): the block
table (the lightweight key->offset index) rides in scalar-prefetch SMEM and
*drives the BlockSpec index maps*, so each KV block is DMA'd from wherever it
physically lives in the HBM pool ("scattered ValueLog") straight into VMEM.
After compaction (kv_compaction kernel) the table is the identity and the
same kernel streams contiguously — the TPU analogue of Nezha's sorted
ValueLog restoring sequential reads.

Grid: (B, nkv, nblk); online softmax per (batch, kv-head) with rep q-heads
processed together (rows of an MXU tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_compiler_params = pallas_compiler_params(pltpu)


NEG_INF = -1e30


def _paged_kernel(lengths_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_sc, l_sc, acc_sc, *, block_size: int, n_blocks: int,
                  scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    start = j * block_size
    length = lengths_ref[b]

    @pl.when(start < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (rep, hd)
        k = k_ref[0, 0, :, 0].astype(jnp.float32)        # (bs, hd)
        v = v_ref[0, 0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, pool_k, pool_v, table, length, *,
                                  interpret: bool = False):
    """q: (B, nh, hd); pool_k/v: (B, nblk, bs, nkv, hd); table: (B, nblk);
    length: (B,) int32 valid tokens per sequence."""
    B, nh, hd = q.shape
    nblk, bs, nkv = pool_k.shape[1], pool_k.shape[2], pool_k.shape[3]
    rep = nh // nkv
    qg = q.reshape(B, nkv, rep, hd)
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))

    def q_index(b, h, j, lengths_ref, table_ref):
        return b, h, 0, 0

    def kv_index(b, h, j, lengths_ref, table_ref):
        return b, table_ref[b, j], 0, h, 0     # the indirection

    def o_index(b, h, j, lengths_ref, table_ref):
        return b, h, 0, 0

    kernel = functools.partial(_paged_kernel, block_size=bs, n_blocks=nblk,
                               scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), q_index),
            pl.BlockSpec((1, 1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, 1, bs, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), o_index),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, rep, hd), q.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, table, qg, pool_k, pool_v)
    return out.reshape(B, nh, hd)
