"""jit'd dispatch for KV-pool compaction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kv_compaction.kernel import compact_kv_pool_pallas
from repro.kernels.kv_compaction.ref import compact_kv_pool_ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


@functools.partial(jax.jit, static_argnames=("backend",))
def compact_kv_pool(pool, table, *, backend: str = None):
    """Returns (compacted_pool, identity_table)."""
    backend = backend or default_backend()
    if backend == "reference":
        out = compact_kv_pool_ref(pool, table)
    else:
        out = compact_kv_pool_pallas(pool, table,
                                     interpret=(backend == "pallas_interpret"))
    B, nblk = table.shape
    ident = jnp.tile(jnp.arange(nblk, dtype=table.dtype)[None], (B, 1))
    return out, ident
