"""KV-pool compaction — the paper's GC on TPU.

Re-packs scattered KV-cache blocks into logical (sequential) order: the
Pallas analogue of Nezha's sorted-ValueLog rebuild.  The block table rides in
scalar-prefetch SMEM and drives the INPUT BlockSpec index map; the output is
written with an identity map, so after one pass the pool is contiguous and
decode attention streams at full HBM bandwidth instead of block-granular
gathers.  Pure data movement — zero FLOPs, one read + one write per byte.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_compiler_params = pallas_compiler_params(pltpu)



def _compact_kernel(table_ref, pool_ref, out_ref):
    out_ref[0, 0] = pool_ref[0, 0]


def compact_kv_pool_pallas(pool, table, *, interpret: bool = False):
    """pool: (B, nblk, bs, C); table: (B, nblk). Returns logical-order pool."""
    B, nblk, bs, C = pool.shape

    def in_index(b, i, table_ref):
        return b, table_ref[b, i], 0, 0

    def out_index(b, i, table_ref):
        return b, i, 0, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nblk),
        in_specs=[pl.BlockSpec((1, 1, bs, C), in_index)],
        out_specs=pl.BlockSpec((1, 1, bs, C), out_index),
    )
    return pl.pallas_call(
        _compact_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(table, pool)
