"""Pure-jnp oracle for KV-pool compaction (gather to logical order)."""
from __future__ import annotations

import jax.numpy as jnp


def compact_kv_pool_ref(pool, table):
    """pool: (B, nblk, bs, C); table: (B, nblk) logical->physical.
    Returns the pool re-packed in logical order (identity table)."""
    return jnp.take_along_axis(pool, table[..., None, None], axis=1)
