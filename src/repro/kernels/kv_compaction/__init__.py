from repro.kernels.kv_compaction.ops import compact_kv_pool  # noqa: F401
