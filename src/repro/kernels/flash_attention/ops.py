"""jit'd dispatch wrapper: pallas (TPU), pallas-interpret (CPU validation),
or the pure-jnp reference."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "backend"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, backend: str = None):
    backend = backend or default_backend()
    if backend == "reference":
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(backend == "pallas_interpret"))
