"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, nh, S, hd); k, v: (B, nkv, S, hd). Returns (B, nh, S, hd) f32
    math, cast back to q.dtype."""
    B, nh, Sq, hd = q.shape
    nkv, Skv = k.shape[1], k.shape[2]
    rep = nh // nkv
    qf = q.astype(jnp.float32).reshape(B, nkv, rep, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qf, kf) * hd ** -0.5
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p, vf) / \
        jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return o.reshape(B, nh, Sq, hd).astype(q.dtype)
