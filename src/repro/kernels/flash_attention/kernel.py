"""Causal GQA flash attention — Pallas TPU kernel.

Tiling: grid (B*nh, Sq/Bq, Skv/Bk); the kv dimension is innermost and
"arbitrary" (sequential) so the online-softmax state (m, l, acc) lives in
VMEM scratch across kv steps.  GQA is handled in the K/V BlockSpec index
maps (q-head -> kv-head division) — no materialized head repeat, KV is read
once per q tile.  MXU-aligned tiles: Bq, Bk multiples of 128 where the
sequence allows; head_dim padded to the lane width by the caller if needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

_compiler_params = pallas_compiler_params(pltpu)


NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  block_q: int, block_k: int, causal: bool, scale: float,
                  n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = qi * block_q
    k_start = kj * block_k
    # causal: skip blocks strictly above the diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (Bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (Bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_cur = jnp.max(s, axis=1)[:, None]              # (Bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new
        l_sc[...] = l_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (B, nh, S, hd); k, v: (B, nkv, S, hd)."""
    B, nh, Sq, hd = q.shape
    nkv, Skv = k.shape[1], k.shape[2]
    rep = nh // nkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    qf = q.reshape(B * nh, Sq, hd)
    kf = k.reshape(B * nkv, Skv, hd)
    vf = v.reshape(B * nkv, Skv, hd)

    def kv_index(bh, i, j):
        return (bh // nh) * nkv + (bh % nh) // rep, j, 0

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=hd ** -0.5, n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * nh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, nh, Sq, hd)
