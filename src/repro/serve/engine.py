"""Paged-KV serving engine with continuous batching and Nezha-style cache GC.

The KV pool is the serving-side ValueLog (DESIGN.md §2): blocks are written
once at their allocation site; the per-sequence block table is the lightweight
key->offset index.  Slot reuse scrambles the physical layout over time
(fragmentation) exactly like Nezha's arrival-order ValueLog; `compact()` is
the GC — it re-packs each live sequence's blocks into logical order
(kernels/kv_compaction) so long decodes stream sequential HBM reads again.
Three-phase reads: compaction swaps the pool atomically per layer while the
old pool stays valid, so in-flight lookups never see a hole.

Scheduler: admit-on-free-slot continuous batching; one engine `step()` =
(admit+prefill new requests) + (one lockstep decode token for every active
sequence, ragged positions via the per-seq `pos` vector).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.kv_compaction.ops import compact_kv_pool
from repro.models import forward, init_cache, init_params


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    submitted: float = 0.0
    finished: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, max_slots: int = 4,
                 max_seq: int = 256, seed: int = 0, rules=None,
                 scramble_blocks: bool = True):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.rules = rules
        self.scramble = scramble_blocks
        self.rng = np.random.default_rng(seed)
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(seed), cfg)
        self.caches = init_cache(cfg, max_slots, max_seq, "paged")
        self.pos = np.zeros(max_slots, np.int64)
        self.active: Dict[int, Request] = {}
        self.queue: "collections.deque[Request]" = collections.deque()
        self.free_slots = list(range(max_slots))
        self.finished: List[Request] = []
        self.decode_steps = 0
        self.compactions = 0
        self._rid = 0

        def decode_fn(params, caches, tokens, pos):
            logits, new_caches = forward(params, tokens, cfg, rules,
                                         mode="decode", caches=caches,
                                         pos=pos)
            return jnp.argmax(logits[:, -1], axis=-1), new_caches

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

        def prefill_fn(params, caches, tokens):
            logits, new_caches = forward(params, tokens, cfg, rules,
                                         mode="prefill", caches=caches)
            return logits, new_caches

        self._prefill = jax.jit(prefill_fn)

    # ------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        self._rid += 1
        req = Request(self._rid, list(prompt), max_new, submitted=time.time())
        self.queue.append(req)
        return req

    # ---------------------------------------------------------- scheduler
    def _slot_cache(self, slot: int):
        return jax.tree.map(lambda a: a[:, slot:slot + 1], self.caches)

    def _write_slot_cache(self, slot: int, sub):
        self.caches = jax.tree.map(
            lambda a, u: a.at[:, slot:slot + 1].set(u.astype(a.dtype)),
            self.caches, sub)

    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            req.slot = slot
            plen = len(req.prompt)
            assert plen + req.max_new <= self.max_seq
            # fragmented allocation: reused slots get scrambled block order
            sub = self._slot_cache(slot)
            sub = self._fresh_slot_tables(sub)
            toks = np.zeros((1, self.max_seq), np.int32)
            toks[0, :plen] = req.prompt
            logits, sub = self._prefill(self.params, sub, jnp.asarray(toks))
            nxt = int(jnp.argmax(logits[0, plen - 1]))
            req.out.append(nxt)
            self._write_slot_cache(slot, sub)
            self.pos[slot] = plen
            self.active[slot] = req

    def _fresh_slot_tables(self, sub):
        def reset(path, a):
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            if name.endswith("table"):
                nblk = a.shape[-1]
                perm = (self.rng.permutation(nblk) if self.scramble
                        else np.arange(nblk)).astype(np.int32)
                return jnp.asarray(perm).reshape((1,) * (a.ndim - 1) + (nblk,)) \
                    * jnp.ones(a.shape, jnp.int32)
            if a.dtype == jnp.int32:
                return a
            return jnp.zeros_like(a)
        return jax.tree_util.tree_map_with_path(reset, sub)

    def step(self) -> int:
        """One engine iteration; returns number of tokens produced."""
        self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.out[-1]
        if self.cfg.input_kind == "embeds":
            tok_in = jnp.zeros((self.max_slots, 1, self.cfg.d_model),
                               jnp.dtype(self.cfg.param_dtype))
        else:
            tok_in = jnp.asarray(tokens)
        pos = jnp.asarray(np.maximum(self.pos, 0), jnp.int32)
        nxt, self.caches = self._decode(self.params, self.caches, tok_in, pos)
        nxt = np.asarray(nxt)
        produced = 0
        for slot in list(self.active):
            req = self.active[slot]
            self.pos[slot] += 1
            req.out.append(int(nxt[slot]))
            produced += 1
            if len(req.out) - 1 >= req.max_new:
                req.done = True
                req.finished = time.time()
                self.finished.append(req)
                del self.active[slot]
                self.free_slots.append(slot)
        self.decode_steps += 1
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        total = 0
        for _ in range(max_steps):
            n = self.step()
            total += n
            if not self.active and not self.queue:
                return total
        raise TimeoutError("serving engine did not drain")

    # ------------------------------------------------------------- the GC
    def fragmentation(self) -> float:
        """Fraction of non-identity block-table entries (scatter level)."""
        leaves = [l for p, l in
                  jax.tree_util.tree_flatten_with_path(self.caches)[0]
                  if "table" in "".join(str(getattr(k, "key", k))
                                        for k in p)]
        total = ident = 0
        for t in leaves:
            t = np.asarray(t)
            ref = np.arange(t.shape[-1])
            ident += (t == ref).sum()
            total += t.size
        return 1.0 - ident / max(total, 1)

    def compact(self, backend: str = None):
        """Nezha GC for the KV pool: gather every live sequence's blocks into
        logical order and reset tables to identity.  Old pool remains valid
        until the per-layer swap (three-phase read safety)."""
        def fix(path, a):
            return a
        # operate per attention cache group: pool_k/pool_v/table triplets
        def compact_group(group):
            if "pool_k" not in group:
                return group
            pk, pv, tb = group["pool_k"], group["pool_v"], group["table"]
            shp = pk.shape                     # (reps, B, nblk, bs, nkv, hd)
            flat_k = pk.reshape((-1,) + shp[2:4] + (shp[4] * shp[5],))
            flat_v = pv.reshape((-1,) + shp[2:4] + (shp[4] * shp[5],))
            flat_t = jnp.broadcast_to(tb, shp[:2] + tb.shape[2:]).reshape(
                (-1, tb.shape[-1]))
            new_k, ident = compact_kv_pool(flat_k, flat_t, backend=backend)
            new_v, _ = compact_kv_pool(flat_v, flat_t, backend=backend)
            return dict(group,
                        pool_k=new_k.reshape(shp), pool_v=new_v.reshape(shp),
                        table=ident.reshape(tb.shape))

        def walk(tree):
            if isinstance(tree, dict):
                if "pool_k" in tree:
                    return compact_group(tree)
                return {k: walk(v) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                return type(tree)(walk(v) for v in tree)
            return tree

        self.caches = walk(self.caches)
        self.compactions += 1
