"""Small shared utilities."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np


def path_str(path: Tuple[Any, ...]) -> str:
    """Human-readable pytree path ('layers/0/attn/wq')."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), leaf) for p, leaf in flat]


def tree_bytes(tree) -> int:
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def fmt_bytes(n: float) -> str:
    for unit in ["B", "KiB", "MiB", "GiB", "TiB"]:
        if abs(n) < 1024:
            return f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}PiB"
