"""Deterministic discrete-event network for the Raft cluster.

Seeded delays, message drops, and pairwise partitions — the substrate for
fault-injection tests (crash, partition, heal) with fully reproducible
schedules.

Chaos-harness surface (repro/core/workload.py rides on all three):
  * per-link injection: `set_link(a, b, ...)` overrides the delay range
    and/or adds a lossy window on one {a,b} link — single-link latency
    spikes and asymmetric loss without touching the rest of the fabric;
  * forked RNG streams: `fork_rng(tag)` derives an independent seeded
    stream from (seed, tag), so a chaos schedule can draw randomness
    without perturbing the delivery sequence (same seed => same
    deliveries, with or without chaos consumers);
  * message trace: `enable_trace()` records every send, drop (with the
    reason — down node, removed address, partition, lossy window, crash
    flush) and delivery — the replayable signature the chaos determinism
    test compares across same-seed runs, and the feed that makes
    `dropped_msgs` attributable (`drop_reasons`) instead of a bare
    counter.  When a `repro.core.trace` tracer is installed the same
    records flow into its `net_events` stream, time-aligned with spans.
"""
from __future__ import annotations

import heapq
import random
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from repro.core import trace as _trace


class SimNet:
    def __init__(self, node_ids, seed: int = 0, min_delay: int = 1,
                 max_delay: int = 3, drop_prob: float = 0.0):
        self.time = 0
        self.seed = seed
        self.rng = random.Random(seed)
        self.min_delay, self.max_delay = min_delay, max_delay
        self.drop_prob = drop_prob
        self._q: Dict[int, List[Tuple[int, int, int, Any]]] = {
            n: [] for n in node_ids}
        self._seq = 0
        self.blocked: set = set()      # frozenset({a,b}) pairs
        self.down: set = set()         # crashed nodes
        self.removed: set = set()      # membership-removed node ids
        # per-link overrides: frozenset({a,b}) -> (min_delay, max_delay)
        # and -> drop probability (falls back to the net-wide defaults)
        self.link_delay: Dict[frozenset, Tuple[int, int]] = {}
        self.link_drop: Dict[frozenset, float] = {}
        self.trace: Optional[List[Tuple]] = None
        self.sent_msgs = 0
        self.sent_bytes = 0
        # every message the network discarded, whether refused at send time
        # (down / partitioned / lossy link) or destroyed in-flight by a
        # crash — the sender-visible signal that retry/resume logic (e.g.
        # run-shipping chunk retransmission) must cover.  drop_reasons
        # splits the total by cause: 'down' | 'removed' | 'partition' |
        # 'lossy' | 'crash_flush' | 'removed_flush'.
        self.dropped_msgs = 0
        self.drop_reasons: Dict[str, int] = defaultdict(int)

    def fork_rng(self, tag: str) -> random.Random:
        """Independent seeded stream derived from (seed, tag).  Chaos
        schedules / jitter sources draw here instead of from `rng`, so
        their draws can never shift a delivery delay (determinism)."""
        return random.Random(f"{self.seed}:{tag}")

    def enable_trace(self):
        """Start recording message order; see module docstring.  Records
        are ("send"|"drop"|"deliver", time, dst, src, msg_type[, reason])
        tuples — delivery records keep the historical (dst, src) order."""
        self.trace = []

    def _record(self, kind: str, src: int, dst: int, msg: Any,
                reason: Optional[str] = None):
        name = type(msg).__name__
        if self.trace is not None:
            if reason is None:
                self.trace.append((kind, self.time, dst, src, name))
            else:
                self.trace.append((kind, self.time, dst, src, name, reason))
        t = _trace._ACTIVE
        if t is not None:
            t.net_event(kind, self.time, src, dst, name, reason)

    def _drop(self, src: int, dst: int, msg: Any, reason: str):
        self.dropped_msgs += 1
        self.drop_reasons[reason] += 1
        if self.trace is not None or _trace._ACTIVE is not None:
            self._record("drop", src, dst, msg, reason)

    # ------------------------------------------------------ link injection
    def set_link(self, a: int, b: int, *,
                 min_delay: Optional[int] = None,
                 max_delay: Optional[int] = None,
                 drop_prob: Optional[float] = None):
        """Override one {a,b} link: a delay range (both bounds required
        together) and/or a loss probability.  Unset aspects keep the
        net-wide defaults; clear_link() removes the override."""
        pair = frozenset((a, b))
        if (min_delay is None) != (max_delay is None):
            raise ValueError("set_link needs both delay bounds or neither")
        if min_delay is not None:
            self.link_delay[pair] = (min_delay, max_delay)
        if drop_prob is not None:
            self.link_drop[pair] = drop_prob

    def clear_link(self, a: int = None, b: int = None):
        """Remove one {a,b} override, or every override when a is None."""
        if a is None:
            self.link_delay.clear()
            self.link_drop.clear()
        else:
            pair = frozenset((a, b))
            self.link_delay.pop(pair, None)
            self.link_drop.pop(pair, None)

    # --------------------------------------------------------- membership
    def add_node(self, nid: int):
        """Give a joining node a mailbox (idempotent); a previously
        removed id rejoining comes back with an empty queue."""
        self._q.setdefault(nid, [])
        self.removed.discard(nid)

    def remove_node(self, nid: int):
        """Membership removal: the address is dead forever — queued and
        future mail is destroyed (counted in dropped_msgs) so a zombie
        node can neither receive stale RPCs nor inject new ones."""
        self.removed.add(nid)
        for _, _, src, msg in self._q.get(nid, ()):
            self._drop(src, nid, msg, "removed_flush")
        if nid in self._q:
            self._q[nid].clear()

    # ------------------------------------------------------------ transport
    def send(self, src: int, dst: int, msg: Any, size: int = 0):
        if src in self.removed or dst in self.removed:
            self._drop(src, dst, msg, "removed")
            return
        if src in self.down or dst in self.down:
            self._drop(src, dst, msg, "down")
            return
        pair = frozenset((src, dst))
        if pair in self.blocked:
            self._drop(src, dst, msg, "partition")
            return
        p = self.link_drop.get(pair, self.drop_prob)
        if p and self.rng.random() < p:
            self._drop(src, dst, msg, "lossy")
            return
        lo, hi = self.link_delay.get(pair, (self.min_delay, self.max_delay))
        delay = self.rng.randint(lo, hi)
        self._seq += 1
        # setdefault: mail to a member that is still being provisioned
        # (config committed, node not yet constructed) queues until it
        # starts delivering instead of crashing the sender
        heapq.heappush(self._q.setdefault(dst, []),
                       (self.time + delay, self._seq, src, msg))
        self.sent_msgs += 1
        self.sent_bytes += size
        if self.trace is not None or _trace._ACTIVE is not None:
            self._record("send", src, dst, msg)

    def deliver(self, nid: int) -> List[Tuple[int, Any]]:
        if nid in self.down or nid in self.removed:
            return []
        out = []
        q = self._q.get(nid, [])
        while q and q[0][0] <= self.time:
            _, _, src, msg = heapq.heappop(q)
            if self.trace is not None or _trace._ACTIVE is not None:
                self._record("deliver", src, nid, msg)
            out.append((src, msg))
        return out

    def tick(self):
        self.time += 1

    def partition(self, a: int, b: int):
        self.blocked.add(frozenset((a, b)))

    def heal(self, a: int = None, b: int = None):
        if a is None:
            self.blocked.clear()
        else:
            self.blocked.discard(frozenset((a, b)))

    def crash(self, nid: int):
        self.down.add(nid)
        q = self._q.get(nid)
        if q:
            for _, _, src, msg in q:      # in-flight mail vanishes
                self._drop(src, nid, msg, "crash_flush")
            q.clear()

    def restart(self, nid: int):
        self.down.discard(nid)
