"""Deterministic discrete-event network for the Raft cluster.

Seeded delays, message drops, and pairwise partitions — the substrate for
fault-injection tests (crash, partition, heal) with fully reproducible
schedules.
"""
from __future__ import annotations

import heapq
import random
from typing import Any, Dict, List, Tuple


class SimNet:
    def __init__(self, node_ids, seed: int = 0, min_delay: int = 1,
                 max_delay: int = 3, drop_prob: float = 0.0):
        self.time = 0
        self.rng = random.Random(seed)
        self.min_delay, self.max_delay = min_delay, max_delay
        self.drop_prob = drop_prob
        self._q: Dict[int, List[Tuple[int, int, int, Any]]] = {
            n: [] for n in node_ids}
        self._seq = 0
        self.blocked: set = set()      # frozenset({a,b}) pairs
        self.down: set = set()         # crashed nodes
        self.sent_msgs = 0
        self.sent_bytes = 0
        # every message the network discarded, whether refused at send time
        # (down / partitioned / lossy link) or destroyed in-flight by a
        # crash — the sender-visible signal that retry/resume logic (e.g.
        # run-shipping chunk retransmission) must cover
        self.dropped_msgs = 0

    def send(self, src: int, dst: int, msg: Any, size: int = 0):
        if src in self.down or dst in self.down:
            self.dropped_msgs += 1
            return
        if frozenset((src, dst)) in self.blocked:
            self.dropped_msgs += 1
            return
        if self.drop_prob and self.rng.random() < self.drop_prob:
            self.dropped_msgs += 1
            return
        delay = self.rng.randint(self.min_delay, self.max_delay)
        self._seq += 1
        heapq.heappush(self._q[dst], (self.time + delay, self._seq, src, msg))
        self.sent_msgs += 1
        self.sent_bytes += size

    def deliver(self, nid: int) -> List[Tuple[int, Any]]:
        if nid in self.down:
            return []
        out = []
        q = self._q[nid]
        while q and q[0][0] <= self.time:
            _, _, src, msg = heapq.heappop(q)
            out.append((src, msg))
        return out

    def tick(self):
        self.time += 1

    def partition(self, a: int, b: int):
        self.blocked.add(frozenset((a, b)))

    def heal(self, a: int = None, b: int = None):
        if a is None:
            self.blocked.clear()
        else:
            self.blocked.discard(frozenset((a, b)))

    def crash(self, nid: int):
        self.down.add(nid)
        self.dropped_msgs += len(self._q[nid])   # in-flight mail vanishes
        self._q[nid].clear()

    def restart(self, nid: int):
        self.down.discard(nid)
