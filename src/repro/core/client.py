"""Consistency-tiered client surface for the Nezha cluster.

The paper guarantees strong consistency through Raft, but a client that
reads the leader's engine directly gets none of it: a deposed leader on the
minority side of a partition happily serves state that the majority has
already overwritten, and every read serializes through one node.  This
module is the ladder of read tiers that fixes both, mirroring the engine's
three replication tiers (engines.py):

  LINEARIZABLE  ReadIndex (Raft §6.4): the leader records its commit index,
                confirms leadership with ONE heartbeat-quorum round that
                covers every read queued at that moment (RaftNode batches
                the probe), and serves once applied >= the read index.
                Safe under partition: a deposed leader can never confirm,
                so the read is refused (StaleReadError) or redirected.
  LEASE         The leader serves locally while it holds a tick-based
                lease renewed by heartbeat acks (lease_ticks < minimum
                election timeout, so the lease expires before any new
                leader can exist).  Zero quorum rounds under a stable
                leader; falls back to LINEARIZABLE when the lease lapsed.
  SESSION       Served by ANY live node — including followers, turning
                them into read capacity for the first time.  A per-session
                token carries the client's last-seen raft index; a node
                serves only once it has applied at least that far
                (read-your-writes + monotonic reads, à la Roohitavaf et
                al.'s session guarantees over Raft).  With run shipping on
                (the NezhaEngine default) followers hold the same sealed
                run sets as the leader, so SESSION scans are byte-equal
                with the leader and aggregate scan throughput scales with
                cluster size (benchmarks/fig_reads.py).

Writes (`put`/`put_many`) always go through the leader's log; the
leadership-change retry lives HERE, as a loop (not recursion), so tests
and benchmarks stop re-implementing it.

Every read is accounted on the serving node's Metrics (on_read_tier /
on_read_quorum_round) and surfaced through Cluster.read_report() — the
single evidence path shared by the fig_reads benchmark, the smoke gate and
the stale-read regression tests.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from repro.core import trace as _trace
from repro.core.raft import LEADER, RaftNode

LINEARIZABLE = "linearizable"
LEASE = "lease"
SESSION = "session"

CONSISTENCY_LEVELS = (LINEARIZABLE, LEASE, SESSION)


class StaleReadError(Exception):
    """The contacted node refused the read rather than risk staleness:
    an unconfirmable (deposed/partitioned) leader for LINEARIZABLE/LEASE,
    or a node whose applied state lags the session token for SESSION."""


class NodeRemovedError(StaleReadError):
    """The contacted node was removed from the cluster membership: its
    address is permanently dead (SimNet destroys its mail), so a pinned
    or session-routed read must fail fast with this instead of hanging
    on a dead mailbox.  Subclasses StaleReadError so existing refusal
    handling keeps working; unpinned reads simply route around it."""


class Session:
    """Client session: a token (`last_index`) of the newest raft index this
    client has observed — via its own writes or previous reads.  Any node
    that has applied at least that far may serve the session's reads."""

    def __init__(self, client: "NezhaClient"):
        self.client = client
        self.last_index = 0

    def observe(self, index: Optional[int]):
        """Fold an observed raft index into the token (monotonic)."""
        if index is not None and index > self.last_index:
            self.last_index = index

    # ------------------------------------------------------------- sugar
    def put(self, key: bytes, value: bytes, **kw) -> int:
        idx = self.client.put(key, value, **kw)
        self.observe(idx)
        return idx

    def put_many(self, items, **kw) -> int:
        # the client observes each chunk's max raft index into the token
        # as it confirms — exact read-your-writes, not a guess at the
        # current leader's applied point
        return self.client.put_many(items, session=self, **kw)

    def get(self, key: bytes, *, node: Optional[int] = None):
        return self.client.get(key, SESSION, session=self, node=node)

    def scan(self, lo: bytes, hi: bytes, *, node: Optional[int] = None):
        return self.client.scan(lo, hi, SESSION, session=self, node=node)


class NezhaClient:
    """Cluster-facing client: consistency-tiered reads, loop-retried
    writes, leader redirect handled internally.

    `node=` pins an operation to one node (the regression tests point it
    at a deposed leader; fig_reads spreads scans across followers); unpinned
    reads pick the leader (LINEARIZABLE/LEASE) or rotate round-robin over
    live nodes (SESSION)."""

    def __init__(self, cluster, *, default_consistency: str = LINEARIZABLE,
                 read_ticks: int = 400, stall_ticks: int = 120,
                 put_attempts: int = 100):
        if default_consistency not in CONSISTENCY_LEVELS:
            raise ValueError(f"unknown consistency {default_consistency!r}")
        self.cluster = cluster
        self.default_consistency = default_consistency
        self.read_ticks = read_ticks      # budget for one quorum round
        self.stall_ticks = stall_ticks    # session wait before redirecting
        self.put_attempts = put_attempts
        self._rr = 0                      # session read round-robin cursor

    def session(self) -> Session:
        return Session(self)

    # -------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes, max_ticks: int = 2000) -> int:
        """Committed write through the current leader.  Leadership churn
        retries via a bounded LOOP — the old Cluster.put recursed here,
        which meant unbounded stack depth under churny elections.

        Under an installed tracer this is a ROOT span: everything the put
        causes — the leader's append+fsync, follower appends, the apply,
        GC work piggybacked on post_op — hangs off it, across nodes."""
        t = _trace._ACTIVE
        sid = t.begin("put", kind="op",
                      key=key.decode("utf-8", "replace")) \
            if t is not None else None
        try:
            return self._put_locked(key, value, max_ticks, t, sid)
        finally:
            if sid is not None:
                t.end(sid)

    def _put_locked(self, key, value, max_ticks, t, sid) -> int:
        c = self.cluster
        for _ in range(self.put_attempts):
            ld = c.elect()
            idx = ld.client_put(key, value)
            if idx is None:               # lost leadership since elect()
                continue
            retry = False
            for _ in range(max_ticks):
                if ld.last_applied >= idx:
                    for e in c.engines:
                        if e is not None:
                            e.post_op()
                    if t is not None:
                        t.event("client_ack", ld.addr, idx)
                        t.tag(sid, index=idx, leader=ld.addr)
                    return idx
                c.tick()
                # a deposed leader may KEEP role=LEADER while partitioned;
                # watching the cluster's max-term leader catches that too
                if ld.role != LEADER or c.leader() is not ld:
                    retry = True
                    break
            if not retry:
                raise TimeoutError("put not committed")
        raise TimeoutError("put: leadership never stabilized")

    def put_many(self, items: Iterable[Tuple[bytes, bytes]],
                 window: int = 64, max_ticks: int = 200000,
                 batch: Optional[int] = None,
                 session: Optional[Session] = None) -> int:
        """Pipelined group-committed puts: submit in `batch`-sized windows
        (client_put_many => one buffered write + one fsync per window) and
        keep up to `window` entries in flight.

        In-flight chunks are tracked WITH their items: if leadership moves
        mid-flight, raft indexes the old leader assigned may now name
        different entries in the new leader's log, so every unconfirmed
        chunk is resubmitted to the new leader (at-least-once, like put)
        instead of being silently counted as committed.  A chunk counts
        as done — and feeds `session`'s read-your-writes token — only when
        its OWN indexes are applied on the leader that assigned them."""
        t = _trace._ACTIVE
        sid = t.begin("put_many", kind="op") if t is not None else None
        try:
            return self._put_many_locked(items, window, max_ticks, batch,
                                         session, t, sid)
        finally:
            if sid is not None:
                t.end(sid)

    def _put_many_locked(self, items, window, max_ticks, batch, session,
                         t, sid) -> int:
        c = self.cluster
        ld = c.elect()
        if batch is None:
            batch = max(1, min(window, ld.max_batch))

        def submit(chunk):
            nonlocal ld
            idxs = ld.client_put_many(chunk)
            while idxs is None:            # deposed since elect(): re-elect
                ld = c.elect()
                idxs = ld.client_put_many(chunk)
            return idxs

        it = iter(items)
        inflight: List[Tuple[list, List[int]]] = []   # (chunk items, idxs)
        done = 0
        exhausted = False
        for _ in range(max_ticks):
            npending = sum(len(idxs) for _, idxs in inflight)
            while not exhausted and npending < window:
                chunk = []
                room = min(batch, window - npending)
                while len(chunk) < room:
                    nxt = next(it, None)
                    if nxt is None:
                        exhausted = True
                        break
                    chunk.append(nxt)
                if not chunk:
                    break
                inflight.append((chunk, submit(chunk)))
                npending += len(chunk)
            if inflight:
                c.tick()
                if ld.role != LEADER or c.leader() is not ld:
                    # leadership changed: nothing still in flight can be
                    # trusted by index — resubmit it all to the new leader
                    ld = c.elect()
                    inflight = [(chunk, submit(chunk))
                                for chunk, _ in inflight]
                applied = ld.last_applied
                keep = []
                for chunk, idxs in inflight:
                    # idxs ascend with the chunk's items, so the confirmed
                    # part is exactly a prefix; keeping item/index pairs
                    # aligned means a later resubmit sends ONLY the
                    # unconfirmed suffix (already-counted items must not
                    # be counted — or resubmitted — twice)
                    ok = sum(1 for i in idxs if i <= applied)
                    done += ok
                    if t is not None and ok:
                        t.event("client_ack", ld.addr, idxs[ok - 1])
                    if session is not None and ok:
                        session.observe(idxs[ok - 1])
                    if ok < len(idxs):
                        keep.append((chunk[ok:], idxs[ok:]))
                inflight = keep
                for e in c.engines:
                    if e is not None:
                        e.post_op()
            if exhausted and not inflight:
                return done
        raise TimeoutError(
            f"put_many stalled: {done} done, "
            f"{sum(len(x[1]) for x in inflight)} pending")

    # --------------------------------------------------------------- reads
    def get(self, key: bytes, consistency: Optional[str] = None, *,
            session: Optional[Session] = None,
            node: Optional[int] = None) -> Optional[bytes]:
        return self._read(lambda eng: eng.get(key), consistency,
                          session=session, node=node, op_name="get")

    def scan(self, lo: bytes, hi: bytes, consistency: Optional[str] = None,
             *, session: Optional[Session] = None,
             node: Optional[int] = None):
        return self._read(lambda eng: eng.scan(lo, hi), consistency,
                          session=session, node=node, op_name="scan")

    def get_many(self, keys: List[bytes]) -> List[Optional[bytes]]:
        """Batched LINEARIZABLE gets: every key's ReadHandle is queued
        before the next tick, so ONE heartbeat-quorum round confirms the
        whole batch — N reads, 1 round (assertable via read_report)."""
        c = self.cluster
        t = _trace._ACTIVE
        sid = t.begin("get_many", kind="op", tier=LINEARIZABLE,
                      n=len(keys)) if t is not None else None
        try:
            for _ in range(8):
                nd = c.elect()
                handles = [nd.read_index_submit() for _ in keys]
                if any(h is None for h in handles):
                    continue
                if self._await_handles(handles):
                    eng, m = c.engines[nd.nid], c.metrics[nd.nid]
                    out = []
                    for k in keys:
                        m.on_read_tier(LINEARIZABLE)
                        out.append(eng.get(k))
                    return out
            raise StaleReadError("get_many: leadership never confirmed")
        finally:
            if sid is not None:
                t.end(sid)

    def _await_handles(self, handles) -> bool:
        """Tick until every ReadHandle is ready (True) or any aborts /
        the budget runs out (False; stragglers are aborted so the node
        prunes them from its queue).  The one confirm/wait state machine
        shared by the serial and batched linearizable paths."""
        c = self.cluster
        for _ in range(self.read_ticks):
            if all(h.ready for h in handles):
                return True
            if any(h.aborted for h in handles):
                return False
            c.tick()
        for h in handles:
            h.aborted = True
        return False

    def _read(self, op, consistency: Optional[str], *,
              session: Optional[Session], node: Optional[int],
              op_name: str = "read"):
        tier = consistency or \
            (SESSION if session is not None else self.default_consistency)
        if tier not in CONSISTENCY_LEVELS:
            raise ValueError(f"unknown consistency {tier!r}")
        t = _trace._ACTIVE
        sid = t.begin(op_name, kind="op", tier=tier) \
            if t is not None else None
        try:
            if tier == SESSION:
                return self._read_session(op, session, node)
            if tier == LEASE:
                return self._read_lease(op, node)
            return self._read_linearizable(op, node)
        finally:
            if sid is not None:
                t.end(sid)

    # ------------------------------------------------------- linearizable
    def _pinned(self, node: Optional[int]) -> Optional[RaftNode]:
        if node is not None and node in getattr(self.cluster, "removed",
                                                ()):
            raise NodeRemovedError(
                f"node {node} was removed from the cluster membership")
        nd = self.cluster.nodes[node] if node is not None else None
        if node is not None and (nd is None or
                                 self.cluster.addr(node) in
                                 self.cluster.net.down):
            raise StaleReadError(f"node {node} is down")
        return nd

    def _read_linearizable(self, op, node: Optional[int] = None):
        c = self.cluster
        for _ in range(8):
            nd = self._pinned(node) or c.elect()
            h = nd.read_index_submit()
            if h is None:
                if node is not None:
                    raise StaleReadError(
                        f"node {node} is not the leader")
                continue
            if self._await_handles([h]):
                c.metrics[nd.nid].on_read_tier(LINEARIZABLE)
                return op(c.engines[nd.nid])
            if node is not None:
                # pinned read refused: the node lost leadership or could
                # not confirm it within budget (minority partition)
                raise StaleReadError(
                    f"node {node} could not confirm leadership: "
                    "refusing possibly-stale read")
        raise StaleReadError("linearizable read: no confirmable leader")

    # -------------------------------------------------------------- lease
    def _read_lease(self, op, node: Optional[int] = None):
        c = self.cluster
        nd = self._pinned(node) or c.elect()
        if nd.lease_valid():
            read_index = nd.commit_index
            for _ in range(self.read_ticks):
                if nd.last_applied >= read_index:
                    c.metrics[nd.nid].on_read_tier(LEASE)
                    return op(c.engines[nd.nid])
                c.tick()
                if not nd.lease_valid():
                    break             # expired while waiting on apply
        # no (or lapsed) lease: pay the quorum round — which renews it
        return self._read_linearizable(op, node)

    # ------------------------------------------------------------ session
    def _read_session(self, op, session: Optional[Session],
                      node: Optional[int] = None):
        c = self.cluster
        self._pinned(node)                # uniform down-node diagnostic
        token = session.last_index if session is not None else 0
        if node is not None:
            candidates = [node]
        else:
            n = len(c.nodes)
            self._rr += 1
            candidates = [(self._rr + k) % n for k in range(n)]
        removed = getattr(c, "removed", ())
        candidates = [nid for nid in candidates
                      if c.nodes[nid] is not None
                      and c.addr(nid) not in c.net.down
                      and nid not in removed]

        def serve(nid, stalled):
            nd = c.nodes[nid]
            c.metrics[nid].on_read_tier(
                SESSION, follower=nd.role != LEADER, stalled=stalled)
            out = op(c.engines[nid])
            if session is not None:
                session.observe(nd.last_applied)
            return out

        # pass 1: some candidate may already satisfy the token — don't
        # burn the stall budget on a laggard when a caught-up node exists
        for nid in candidates:
            if c.nodes[nid].last_applied >= token:
                return serve(nid, stalled=False)
        # pass 2: everyone lags; wait on the apply pipeline (one shared
        # budget — ticks advance every node at once)
        for _ in range(self.stall_ticks):
            c.tick()
            for nid in candidates:
                if c.nodes[nid].last_applied >= token:
                    return serve(nid, stalled=True)
        if node is not None:
            raise StaleReadError(
                f"node {node} applied {c.nodes[node].last_applied} < "
                f"session token {token}: refusing non-monotonic read")
        raise StaleReadError(
            f"no live node has applied session token {token}")
