"""Byte-level I/O accounting — the evidence layer for every paper claim.

Every file write/read in the storage stack is tagged with a category
(raft_log, wal, flush, compaction, valuelog, gc_read, ...), so write
amplification per layer can be reported exactly: the paper's central claim is
"value writes drop from >=3x to exactly 1x" and these counters prove (or
refute) it at any scale.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class Metrics:
    write_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    write_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    fsyncs: int = 0
    cache_hits: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    bloom_skips: int = 0
    # replication traffic this node put on (or took off) the wire, by kind:
    #   'snapshot' — InstallSnapshot run-set payloads (sender side)
    #   'sst'      — LSM-Raft shipped compacted SSTables (receiver side)
    #   'run'      — run-shipping adoption records, per chunk per peer
    #                (sender side)
    # The single channel replaces the old ad-hoc 'snapshot_ship'/'sst_ship'
    # tags so total replication bytes per node is one sum.
    ship_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    ship_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    # consistency-tiered read evidence (client.py): reads served by THIS
    # node, by tier ('linearizable' | 'lease' | 'session'), plus the costs
    # each tier pays — ReadIndex heartbeat-quorum rounds (linearizable /
    # expired-lease fallback), reads a follower served (session: followers
    # become read capacity), and reads that had to stall for the apply
    # pipeline to reach the session token.  One evidence path shared by
    # benchmarks/fig_reads.py, the smoke gate and the stale-read tests
    # (Cluster.read_report()).
    read_tiers: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_quorum_rounds: int = 0
    follower_serves: int = 0
    session_stalls: int = 0
    latencies_us: Dict[str, List[float]] = field(
        default_factory=lambda: defaultdict(list))
    # leveled-GC evidence: one record per completed GC unit of work —
    # {"kind": "flush"|"merge", "bytes": n, "level": l, "cycle": c} — so
    # "per-cycle compaction work stays bounded as data grows" is assertable.
    gc_cycle_log: List[dict] = field(default_factory=list)

    def on_write(self, category: str, nbytes: int):
        self.write_bytes[category] += nbytes
        self.write_ops[category] += 1

    def on_read(self, category: str, nbytes: int):
        self.read_bytes[category] += nbytes
        self.read_ops[category] += 1

    def on_fsync(self):
        self.fsyncs += 1

    def on_cache_hit(self, category: str):
        """A read served from the block cache: zero disk bytes."""
        self.cache_hits[category] += 1

    def on_bloom_skip(self):
        """A point get skipped an SSTable entirely via its bloom filter."""
        self.bloom_skips += 1

    def on_read_tier(self, tier: str, *, follower: bool = False,
                     stalled: bool = False):
        """One client read served by this node at `tier` ('linearizable',
        'lease' or 'session').  `follower` marks a read a non-leader served
        (scalable read capacity); `stalled` marks a session read that had
        to wait for the apply pipeline to reach its token."""
        self.read_tiers[tier] += 1
        if follower:
            self.follower_serves += 1
        if stalled:
            self.session_stalls += 1

    def on_read_quorum_round(self):
        """One ReadIndex heartbeat-quorum round (covers every read queued
        on the leader at that moment — the batching is the point)."""
        self.read_quorum_rounds += 1

    def on_ship(self, kind: str, nbytes: int):
        """One replication payload crossing the network ('snapshot', 'sst'
        or 'run' — see ship_bytes).  Disk I/O caused by the payload is still
        accounted separately through on_read/on_write."""
        self.ship_bytes[kind] += nbytes
        self.ship_ops[kind] += 1

    def total_ship_bytes(self) -> int:
        """All replication bytes this node shipped/adopted over the wire."""
        return sum(self.ship_bytes.values())

    def on_gc_cycle(self, kind: str, nbytes: int, level: int, cycle: int):
        """One completed GC unit: an active-segment flush into L0
        ('flush') or a level-i -> level-i+1 run merge ('merge')."""
        self.gc_cycle_log.append({"kind": kind, "bytes": nbytes,
                                  "level": level, "cycle": cycle})

    def gc_flush_bytes_per_cycle(self) -> List[int]:
        """Bytes each active-segment GC flush rewrote — flat across cycles
        under leveled GC, grows O(total data) under a monolithic rewrite."""
        return [r["bytes"] for r in self.gc_cycle_log if r["kind"] == "flush"]

    def gc_total_bytes(self) -> int:
        """All bytes GC rewrote: L0 flushes + level merges."""
        return sum(v for k, v in self.write_bytes.items()
                   if k in ("gc_sorted", "gc_level_merge"))

    def gc_write_amplification(self, user_bytes: int) -> float:
        return self.gc_total_bytes() / max(user_bytes, 1)

    def record_latency(self, op: str, seconds: float):
        self.latencies_us[op].append(seconds * 1e6)

    def total_writes(self) -> int:
        return sum(self.write_bytes.values())

    def write_amplification(self, user_bytes: int) -> float:
        return self.total_writes() / max(user_bytes, 1)

    def value_write_count(self, user_bytes: int) -> float:
        """How many times each user byte hit the disk (the paper's '>=3 -> 1')."""
        return self.write_amplification(user_bytes)

    def summary(self) -> dict:
        import numpy as np
        lat = {}
        for op, xs in self.latencies_us.items():
            a = np.asarray(xs)
            lat[op] = {"p50_us": float(np.percentile(a, 50)),
                       "p99_us": float(np.percentile(a, 99)),
                       "mean_us": float(a.mean()), "n": len(xs)}
        return {
            "write_bytes": dict(self.write_bytes),
            "read_bytes": dict(self.read_bytes),
            "write_ops": dict(self.write_ops),
            "read_ops": dict(self.read_ops),
            "fsyncs": self.fsyncs,
            "cache_hits": dict(self.cache_hits),
            "bloom_skips": self.bloom_skips,
            "ship_bytes": dict(self.ship_bytes),
            "read_tiers": dict(self.read_tiers),
            "read_quorum_rounds": self.read_quorum_rounds,
            "follower_serves": self.follower_serves,
            "session_stalls": self.session_stalls,
            "latency": lat,
        }


class Stopwatch:
    def __init__(self, metrics: Metrics, op: str):
        self.metrics, self.op = metrics, op

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.metrics.record_latency(self.op, time.perf_counter() - self.t0)
