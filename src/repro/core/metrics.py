"""Byte-level I/O accounting — the evidence layer for every paper claim.

Every file write/read in the storage stack is tagged with a category
(raft_log, wal, flush, compaction, valuelog, gc_read, ...), so write
amplification per layer can be reported exactly: the paper's central claim is
"value writes drop from >=3x to exactly 1x" and these counters prove (or
refute) it at any scale.
"""
from __future__ import annotations

import math
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import trace as _trace


class LatencyHistogram:
    """HDR-style log-bucketed histogram: O(1) record, bounded relative
    error, exact mergeability.

    Bucket 0 covers [0, min_value); bucket i >= 1 covers
    [min_value * growth^(i-1), min_value * growth^i), so every recorded
    value lands in a bucket whose width is a fixed ~(growth-1) fraction of
    the value — the same trick HdrHistogram uses to cover a huge dynamic
    range in a handful of counters.  Quantiles report the bucket's upper
    edge, so a reported quantile is never below the exact (nearest-rank)
    sample quantile and is within ONE bucket of it (pinned by the minihyp
    property test in tests/test_chaos_harness.py).  merge() of two
    histograms is bucket-exact: identical to the histogram of the
    concatenated samples.

    Units are the caller's choice; the workload harness records
    microseconds (min_value=0.1us resolves sub-microsecond service times,
    ~4%-wide buckets keep p999 honest)."""

    __slots__ = ("min_value", "growth", "_log_g", "counts", "n", "total",
                 "max_seen", "min_seen")

    def __init__(self, min_value: float = 0.1, growth: float = 1.04):
        if min_value <= 0 or growth <= 1:
            raise ValueError("need min_value > 0 and growth > 1")
        self.min_value = min_value
        self.growth = growth
        self._log_g = math.log(growth)
        self.counts: Dict[int, int] = defaultdict(int)
        self.n = 0
        self.total = 0.0
        self.max_seen = 0.0
        self.min_seen = math.inf

    def bucket(self, value: float) -> int:
        if value < self.min_value:
            return 0
        return 1 + int(math.log(value / self.min_value) / self._log_g)

    def bucket_edge(self, idx: int) -> float:
        """Upper edge of bucket `idx` — the quantile representative."""
        return self.min_value * self.growth ** idx

    def record(self, value: float, count: int = 1):
        self.counts[self.bucket(value)] += count
        self.n += count
        self.total += value * count
        if value > self.max_seen:
            self.max_seen = value
        if value < self.min_seen:
            self.min_seen = value

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile, reported as its bucket's upper edge
        (>= the exact sample quantile, < one bucket above it).

        Raises ValueError on an empty histogram — an empty phase has no
        p99, and silently reporting 0.0 once masked a mis-split phase
        window as "latency dropped to zero"."""
        if self.n == 0:
            raise ValueError(
                "quantile(%r) of an empty histogram: no samples recorded "
                "(check the phase window / label filter that built it)" % q)
        rank = max(1, math.ceil(q * self.n))
        cum = 0
        for idx in sorted(self.counts):
            cum += self.counts[idx]
            if cum >= rank:
                return self.bucket_edge(idx)
        return self.bucket_edge(max(self.counts))    # q > 1 safety

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold `other` into self (same geometry required) and return
        self.  Bucket-exact: merge(a, b) == histogram of a's and b's
        samples concatenated."""
        if (self.min_value, self.growth) != (other.min_value, other.growth):
            raise ValueError(
                "histogram geometries differ: cannot merge "
                "(min_value=%r, growth=%r) into (min_value=%r, growth=%r)"
                % (other.min_value, other.growth,
                   self.min_value, self.growth))
        for idx, cnt in other.counts.items():
            self.counts[idx] += cnt
        self.n += other.n
        self.total += other.total
        self.max_seen = max(self.max_seen, other.max_seen)
        self.min_seen = min(self.min_seen, other.min_seen)
        return self

    def copy(self) -> "LatencyHistogram":
        out = LatencyHistogram(self.min_value, self.growth)
        return out.merge(self)

    def summary(self) -> dict:
        if self.n == 0:     # all-zero summary, explicit about emptiness
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                    "p99": 0.0, "p999": 0.0, "max": 0.0}
        return {"n": self.n, "mean": round(self.mean(), 3),
                "p50": round(self.quantile(0.50), 3),
                "p90": round(self.quantile(0.90), 3),
                "p99": round(self.quantile(0.99), 3),
                "p999": round(self.quantile(0.999), 3),
                "max": round(self.max_seen, 3)}


# counter fields covered by Metrics.snapshot()/delta(): per-category dicts
# and flat ints.  gc_cycle_log is summarized by length (gc_cycles).
_SNAP_DICTS = ("write_bytes", "read_bytes", "write_ops", "read_ops",
               "cache_hits", "ship_bytes", "ship_ops", "read_tiers",
               "fault_injections", "membership_events", "fsync_cats")
_SNAP_INTS = ("fsyncs", "bloom_skips", "read_quorum_rounds",
              "follower_serves", "session_stalls")


@dataclass
class Metrics:
    write_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    write_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    fsyncs: int = 0
    # fsyncs by layer ('valuelog', 'wal', 'raft_log', ...): which store's
    # durability sat on the critical path — the per-category counterpart
    # of the flat `fsyncs` total (sum(fsync_cats.values()) == fsyncs)
    fsync_cats: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    cache_hits: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    bloom_skips: int = 0
    # replication traffic this node put on (or took off) the wire, by kind:
    #   'snapshot' — InstallSnapshot run-set payloads (sender side)
    #   'sst'      — LSM-Raft shipped compacted SSTables (receiver side)
    #   'run'      — run-shipping adoption records, per chunk per peer
    #                (sender side)
    # The single channel replaces the old ad-hoc 'snapshot_ship'/'sst_ship'
    # tags so total replication bytes per node is one sum.
    ship_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    ship_ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    # consistency-tiered read evidence (client.py): reads served by THIS
    # node, by tier ('linearizable' | 'lease' | 'session'), plus the costs
    # each tier pays — ReadIndex heartbeat-quorum rounds (linearizable /
    # expired-lease fallback), reads a follower served (session: followers
    # become read capacity), and reads that had to stall for the apply
    # pipeline to reach the session token.  One evidence path shared by
    # benchmarks/fig_reads.py, the smoke gate and the stale-read tests
    # (Cluster.read_report()).
    read_tiers: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    read_quorum_rounds: int = 0
    follower_serves: int = 0
    session_stalls: int = 0
    # injected-fault evidence (FaultFS / chaos): what this node was
    # subjected to, by kind ('hard_crash', 'mid_put_crash', ...) — lets
    # health_report() and the sweep artifacts state exactly how much abuse
    # a passing run absorbed.
    fault_injections: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    # membership-change evidence: config proposals/adoptions, learner
    # promotions, leadership transfers (raft.py self-healing path)
    membership_events: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    latencies_us: Dict[str, List[float]] = field(
        default_factory=lambda: defaultdict(list))
    # leveled-GC evidence: one record per completed GC unit of work —
    # {"kind": "flush"|"merge", "bytes": n, "level": l, "cycle": c} — so
    # "per-cycle compaction work stays bounded as data grows" is assertable.
    gc_cycle_log: List[dict] = field(default_factory=list)
    # which cluster node this Metrics belongs to (None = standalone) —
    # lets the tracer attribute I/O child spans to the node that did the
    # I/O even when the enclosing span is a client-side root
    node: Optional[int] = None

    def on_write(self, category: str, nbytes: int):
        self.write_bytes[category] += nbytes
        self.write_ops[category] += 1
        if _trace._ACTIVE is not None:
            _trace._ACTIVE.io("write", category, nbytes, node=self.node)

    def on_read(self, category: str, nbytes: int):
        self.read_bytes[category] += nbytes
        self.read_ops[category] += 1
        if _trace._ACTIVE is not None:
            _trace._ACTIVE.io("read", category, nbytes, node=self.node)

    def on_fsync(self, category: str = "unlabeled"):
        self.fsyncs += 1
        self.fsync_cats[category] += 1
        if _trace._ACTIVE is not None:
            _trace._ACTIVE.io("fsync", category, 0, node=self.node)

    def on_cache_hit(self, category: str):
        """A read served from the block cache: zero disk bytes."""
        self.cache_hits[category] += 1

    def on_bloom_skip(self):
        """A point get skipped an SSTable entirely via its bloom filter."""
        self.bloom_skips += 1

    def on_read_tier(self, tier: str, *, follower: bool = False,
                     stalled: bool = False):
        """One client read served by this node at `tier` ('linearizable',
        'lease' or 'session').  `follower` marks a read a non-leader served
        (scalable read capacity); `stalled` marks a session read that had
        to wait for the apply pipeline to reach its token."""
        self.read_tiers[tier] += 1
        if follower:
            self.follower_serves += 1
        if stalled:
            self.session_stalls += 1

    def on_fault(self, kind: str):
        """One injected fault applied to this node (kill -9, torn write,
        mid-op crash ...)."""
        self.fault_injections[kind] += 1

    def on_membership(self, kind: str):
        """One membership event on this node ('config_proposed',
        'config_adopted', 'promote', 'transfer')."""
        self.membership_events[kind] += 1

    def on_read_quorum_round(self):
        """One ReadIndex heartbeat-quorum round (covers every read queued
        on the leader at that moment — the batching is the point)."""
        self.read_quorum_rounds += 1

    def on_ship(self, kind: str, nbytes: int):
        """One replication payload crossing the network ('snapshot', 'sst'
        or 'run' — see ship_bytes).  Disk I/O caused by the payload is still
        accounted separately through on_read/on_write."""
        self.ship_bytes[kind] += nbytes
        self.ship_ops[kind] += 1

    def total_ship_bytes(self) -> int:
        """All replication bytes this node shipped/adopted over the wire."""
        return sum(self.ship_bytes.values())

    def on_gc_cycle(self, kind: str, nbytes: int, level: int, cycle: int):
        """One completed GC unit: an active-segment flush into L0
        ('flush') or a level-i -> level-i+1 run merge ('merge')."""
        self.gc_cycle_log.append({"kind": kind, "bytes": nbytes,
                                  "level": level, "cycle": cycle})

    def gc_flush_bytes_per_cycle(self) -> List[int]:
        """Bytes each active-segment GC flush rewrote — flat across cycles
        under leveled GC, grows O(total data) under a monolithic rewrite."""
        return [r["bytes"] for r in self.gc_cycle_log if r["kind"] == "flush"]

    def gc_total_bytes(self) -> int:
        """All bytes GC rewrote: L0 flushes + level merges."""
        return sum(v for k, v in self.write_bytes.items()
                   if k in ("gc_sorted", "gc_level_merge"))

    def gc_write_amplification(self, user_bytes: int) -> float:
        return self.gc_total_bytes() / max(user_bytes, 1)

    def record_latency(self, op: str, seconds: float):
        self.latencies_us[op].append(seconds * 1e6)

    # ------------------------------------------------------ phase windows
    # Every counter above is ENGINE-LIFETIME cumulative; any "how much did
    # phase X cost" report that reads them raw double-counts everything
    # that happened before the phase.  snapshot() freezes the counters and
    # delta() reports only what happened since — the workload harness uses
    # it for pre-fault vs post-fault accounting, fig_reads for per-tier
    # quorum-round pricing and fig_runship for per-phase byte accounting.
    def snapshot(self) -> dict:
        """Frozen copy of every counter (plain dict, JSON-able)."""
        snap = {k: dict(getattr(self, k)) for k in _SNAP_DICTS}
        for k in _SNAP_INTS:
            snap[k] = getattr(self, k)
        snap["gc_cycles"] = len(self.gc_cycle_log)
        return snap

    def delta(self, since: Optional[dict] = None) -> dict:
        """Counter movement since `since` (a snapshot() result); with no
        baseline, the full lifetime totals in snapshot() shape.  Zero
        movement in a category is omitted from the per-category dicts."""
        since = since or {}
        out = {}
        for k in _SNAP_DICTS:
            base = since.get(k, {})
            out[k] = {c: v - base.get(c, 0)
                      for c, v in getattr(self, k).items()
                      if v != base.get(c, 0)}
        for k in _SNAP_INTS:
            out[k] = getattr(self, k) - since.get(k, 0)
        out["gc_cycles"] = len(self.gc_cycle_log) - since.get("gc_cycles", 0)
        return out

    def total_writes(self) -> int:
        return sum(self.write_bytes.values())

    def write_amplification(self, user_bytes: int) -> float:
        return self.total_writes() / max(user_bytes, 1)

    def value_write_count(self, user_bytes: int) -> float:
        """How many times each user byte hit the disk (the paper's '>=3 -> 1')."""
        return self.write_amplification(user_bytes)

    def summary(self) -> dict:
        import numpy as np
        lat = {}
        for op, xs in self.latencies_us.items():
            a = np.asarray(xs)
            lat[op] = {"p50_us": float(np.percentile(a, 50)),
                       "p99_us": float(np.percentile(a, 99)),
                       "mean_us": float(a.mean()), "n": len(xs)}
        return {
            "write_bytes": dict(self.write_bytes),
            "read_bytes": dict(self.read_bytes),
            "write_ops": dict(self.write_ops),
            "read_ops": dict(self.read_ops),
            "fsyncs": self.fsyncs,
            "fsync_cats": dict(self.fsync_cats),
            "cache_hits": dict(self.cache_hits),
            "bloom_skips": self.bloom_skips,
            "ship_bytes": dict(self.ship_bytes),
            "read_tiers": dict(self.read_tiers),
            "read_quorum_rounds": self.read_quorum_rounds,
            "follower_serves": self.follower_serves,
            "session_stalls": self.session_stalls,
            "fault_injections": dict(self.fault_injections),
            "membership_events": dict(self.membership_events),
            "latency": lat,
        }

    # --------------------------------------------------- typed exposition
    def fill_registry(self, reg: Optional["_trace.MetricsRegistry"] = None,
                      **labels: str) -> "_trace.MetricsRegistry":
        """Publish every counter into a labeled `MetricsRegistry`
        (created if not given).  Extra `labels` (e.g. node="2") are
        attached to every family, so a cluster can merge all of its
        nodes' counters into one scrape — the typed replacement for
        reading the ad-hoc dict fields directly."""
        reg = reg or _trace.MetricsRegistry()
        extra_names = tuple(sorted(labels))

        def cat_counter(name, help, d, label="category"):
            fam = reg.counter(name, help, extra_names + (label,))
            for k in sorted(d):
                fam.labels(**dict(labels, **{label: k})).inc(d[k])

        def flat_counter(name, help, v):
            reg.counter(name, help, extra_names).labels(**labels).inc(v)

        cat_counter("repro_write_bytes_total", "bytes written by layer",
                    self.write_bytes)
        cat_counter("repro_read_bytes_total", "bytes read by layer",
                    self.read_bytes)
        cat_counter("repro_write_ops_total", "write ops by layer",
                    self.write_ops)
        cat_counter("repro_read_ops_total", "read ops by layer",
                    self.read_ops)
        cat_counter("repro_fsyncs_total", "fsyncs by layer",
                    self.fsync_cats)
        cat_counter("repro_cache_hits_total", "block-cache hits by layer",
                    self.cache_hits)
        cat_counter("repro_ship_bytes_total",
                    "replication payload bytes by channel",
                    self.ship_bytes, label="channel")
        cat_counter("repro_reads_total", "client reads served by tier",
                    self.read_tiers, label="tier")
        cat_counter("repro_fault_injections_total",
                    "injected faults by kind",
                    self.fault_injections, label="kind")
        cat_counter("repro_membership_events_total",
                    "membership events by kind",
                    self.membership_events, label="kind")
        flat_counter("repro_bloom_skips_total",
                     "point gets skipped via bloom filter",
                     self.bloom_skips)
        flat_counter("repro_read_quorum_rounds_total",
                     "ReadIndex heartbeat-quorum rounds",
                     self.read_quorum_rounds)
        flat_counter("repro_follower_serves_total",
                     "reads served by a non-leader", self.follower_serves)
        flat_counter("repro_session_stalls_total",
                     "session reads that waited for apply",
                     self.session_stalls)
        flat_counter("repro_gc_cycles_total", "completed GC work units",
                     len(self.gc_cycle_log))
        return reg


class Stopwatch:
    """Latency timer; `clock` defaults to wall time but accepts any
    zero-arg callable returning seconds — the workload harness passes a
    SimNet-virtual-time clock so recorded latencies are deterministic
    (immune to container CPU steal)."""

    def __init__(self, metrics: Metrics, op: str, clock=time.perf_counter):
        self.metrics, self.op, self.clock = metrics, op, clock

    def __enter__(self):
        self.t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.metrics.record_latency(self.op, self.clock() - self.t0)
