"""MiniLSM — a real (if miniature) LSM-tree engine standing in for RocksDB.

Implements the pieces whose I/O the paper reasons about:
  * WAL (optional — PASV removes it) with group commit: one buffered write
    + one fsync per commit window instead of one fsync per record,
  * sorted in-memory memtable with a size threshold,
  * SSTable flush (L0), leveled compaction L0 -> L1 (fanout-triggered),
  * point gets (memtable, then SSTs newest-first) and merged range scans.

SSTables use a block-sparse layout: records are grouped into ~4KB blocks;
only the first key, offset, and length of each block stay in memory, plus a
bloom filter over all keys.  Point gets consult the bloom filter first (a
negative costs zero read bytes), then read exactly one block — served from
the engine-wide BlockCache when hot.  File handles persist across reads.

All file traffic goes through Metrics with per-category tags so write
amplification from WAL/flush/compaction is separately visible.
"""
from __future__ import annotations

import os
import struct
from bisect import bisect_left, bisect_right
from typing import Dict, Iterator, List, Optional, Tuple

try:
    from sortedcontainers import SortedDict
except ImportError:  # container image lacks sortedcontainers
    from repro.core.sorteddict import SortedDict

from repro.core.cache import BlockCache, BloomFilter, next_namespace
from repro.core.faultfs import fs_fsync, fs_open, fs_remove
from repro.core.metrics import Metrics

_REC = struct.Struct("<HI")  # key_len, val_len

BLOCK_BYTES = 4 << 10        # target SSTable block size


class SSTable:
    def __init__(self, path: str, metrics: Metrics,
                 cache: Optional[BlockCache] = None):
        self.path = path
        self.metrics = metrics
        self.cache = cache
        self._cache_ns = next_namespace()
        # block-sparse index: first key / file offset / byte length per block
        self.block_keys: List[bytes] = []
        self.block_offs: List[int] = []
        self.block_lens: List[int] = []
        self.bloom: Optional[BloomFilter] = None
        self.n_records = 0
        self.size = 0
        self._f = None  # persistent read handle, opened lazily

    # ----------------------------------------------------------- building
    def _index_records(self, records: Iterator[Tuple[bytes, int]]):
        """Build the block index + bloom from (key, record_len) pairs laid
        out back-to-back from offset 0."""
        off = 0
        blk_len = 0
        for k, rlen in records:
            if blk_len == 0 or blk_len + rlen > BLOCK_BYTES:
                if blk_len:
                    self.block_lens.append(blk_len)
                self.block_keys.append(k)
                self.block_offs.append(off)
                blk_len = 0
            self.bloom.add(k)
            blk_len += rlen
            off += rlen
            self.n_records += 1
        if blk_len:
            self.block_lens.append(blk_len)
        self.size = off

    @staticmethod
    def write(path: str, items: List[Tuple[bytes, bytes]], metrics: Metrics,
              category: str, cache: Optional[BlockCache] = None,
              sync: bool = False) -> "SSTable":
        with fs_open(path, "wb") as f:    # ONE buffered write for the table
            sst = SSTable(path, metrics, cache)
            sst.bloom = BloomFilter(len(items))
            chunks = []
            lens = []
            for k, v in items:
                rec = _REC.pack(len(k), len(v)) + k + v
                chunks.append(rec)
                lens.append(len(rec))
            f.write(b"".join(chunks))
            if sync:   # durable before the WAL that covers it is truncated
                fs_fsync(f)
        sst._index_records(zip((k for k, _ in items), lens))
        metrics.on_write(category, sst.size)
        return sst

    @staticmethod
    def load(path: str, metrics: Metrics,
             cache: Optional[BlockCache] = None,
             chunk_bytes: int = 1 << 20) -> "SSTable":
        """Stream-decode the file in chunks (no whole-file buffer)."""
        sst = SSTable(path, metrics, cache)
        sst.bloom = BloomFilter(max(os.path.getsize(path) // 32, 64))
        def records():
            with open(path, "rb") as f:
                buf = b""
                while True:
                    chunk = f.read(chunk_bytes)
                    if not chunk and not buf:
                        return
                    buf += chunk
                    off = 0
                    while off + _REC.size <= len(buf):
                        klen, vlen = _REC.unpack_from(buf, off)
                        rlen = _REC.size + klen + vlen
                        if off + rlen > len(buf):
                            break
                        yield buf[off + _REC.size: off + _REC.size + klen], \
                            rlen
                        off += rlen
                    buf = buf[off:]
                    if not chunk:
                        return
        sst._index_records(records())
        return sst

    # -------------------------------------------------------------- reads
    def _read_block(self, i: int, category: str) -> bytes:
        if self.cache is not None:
            blk = self.cache.get(self._cache_ns, i)
            if blk is not None:
                self.metrics.on_cache_hit(category)
                return blk
        if self._f is None:
            self._f = open(self.path, "rb")
        self._f.seek(self.block_offs[i])
        blk = self._f.read(self.block_lens[i])
        self.metrics.on_read(category, len(blk))
        if self.cache is not None:
            self.cache.put(self._cache_ns, i, blk)
        return blk

    @staticmethod
    def _iter_block(blk: bytes) -> Iterator[Tuple[bytes, bytes]]:
        off = 0
        while off + _REC.size <= len(blk):
            klen, vlen = _REC.unpack_from(blk, off)
            k = blk[off + _REC.size: off + _REC.size + klen]
            v = blk[off + _REC.size + klen: off + _REC.size + klen + vlen]
            yield k, v
            off += _REC.size + klen + vlen

    def get(self, key: bytes) -> Optional[bytes]:
        if not self.block_keys:
            return None
        if self.bloom is not None and key not in self.bloom:
            self.metrics.on_bloom_skip()    # negative: ZERO read bytes
            return None
        i = bisect_right(self.block_keys, key) - 1
        if i < 0:
            return None
        for k, v in self._iter_block(self._read_block(i, "sst_point")):
            if k == key:
                return v
        return None                          # bloom false positive

    def range(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        if not self.block_keys or lo > hi:
            return
        i = max(bisect_right(self.block_keys, lo) - 1, 0)
        j = bisect_right(self.block_keys, hi)
        for b in range(i, j):
            for k, v in self._iter_block(self._read_block(b, "sst_range")):
                if lo <= k <= hi:
                    yield k, v

    def items(self) -> Iterator[Tuple[bytes, bytes]]:
        """Sequential full-table read (compaction path) — one big read,
        bypassing the block cache so scans don't evict hot point blocks."""
        if not self.block_keys:
            return
        if self._f is None:
            self._f = open(self.path, "rb")
        self._f.seek(0)
        buf = self._f.read(self.size)
        self.metrics.on_read("sst_range", len(buf))
        yield from self._iter_block(buf)

    def delete(self):
        if self._f is not None:
            self._f.close()
            self._f = None
        if self.cache is not None:
            self.cache.invalidate(self._cache_ns)
        fs_remove(self.path)

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None


class MiniLSM:
    def __init__(self, dirpath: str, metrics: Metrics, *, wal: bool = True,
                 memtable_limit: int = 1 << 22, l0_limit: int = 4,
                 name: str = "lsm", sync: bool = False,
                 group_commit: bool = False,
                 cache: Optional[BlockCache] = None):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics
        self.wal_enabled = wal
        self.memtable_limit = memtable_limit
        self.l0_limit = l0_limit
        self.name = name
        self.sync = sync
        self.group_commit = group_commit
        self.cache = cache
        self.mem: SortedDict = SortedDict()
        self.mem_bytes = 0
        self.l0: List[SSTable] = []
        self.l1: List[SSTable] = []
        self._sst_seq = 0
        self._wal_path = os.path.join(dirpath, "wal.log")
        self._wal = fs_open(self._wal_path, "ab") if wal else None
        self._wal_dirty = False
        self.compaction_count = 0

    # ------------------------------------------------------------- writes
    def _wal_write(self, data: bytes):
        self._wal.write(data)
        self._wal_dirty = True
        if self.sync and not self.group_commit:
            self.sync_wal()

    def put(self, key: bytes, value: bytes):
        if self._wal is not None:
            rec = _REC.pack(len(key), len(value)) + key + value
            self._wal_write(rec)
            self.metrics.on_write("wal", len(rec))
        self._mem_put(key, value)
        if self.mem_bytes >= self.memtable_limit:
            self.flush()

    def put_batch(self, items: List[Tuple[bytes, bytes]]):
        """Group commit: the whole batch becomes ONE buffered WAL write
        (and one fsync at the window boundary); per-record byte accounting
        is unchanged."""
        if self._wal is not None and items:
            recs = []
            for k, v in items:
                rec = _REC.pack(len(k), len(v)) + k + v
                recs.append(rec)
                self.metrics.on_write("wal", len(rec))
            self._wal_write(b"".join(recs))
        for k, v in items:
            self._mem_put(k, v)
        if self.mem_bytes >= self.memtable_limit:
            self.flush()

    def _mem_put(self, key: bytes, value: bytes):
        old = self.mem.get(key)
        self.mem[key] = value
        self.mem_bytes += len(key) + len(value) - \
            (len(key) + len(old) if old is not None else 0)

    def sync_wal(self):
        """Commit-window boundary: one flush + fsync for all buffered WAL
        records since the last boundary."""
        if self._wal is None or not self._wal_dirty:
            return
        self._wal.flush()
        if self.sync:
            fs_fsync(self._wal)
            self.metrics.on_fsync("wal")
        self._wal_dirty = False

    def _truncate_wal(self):
        """Atomically drop all WAL records (memtable made durable): a single
        in-place truncate on the open append handle — no close/reopen."""
        if self._wal is None:
            return
        self._wal.flush()
        self._wal.truncate(0)
        self._wal_dirty = False

    def flush(self):
        if not self.mem:
            return
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        self.l0.append(SSTable.write(path, list(self.mem.items()),
                                     self.metrics, "flush", self.cache,
                                     sync=self.sync))
        self.mem.clear()
        self.mem_bytes = 0
        self._truncate_wal()
        if len(self.l0) > self.l0_limit:
            self.compact()

    def compact(self):
        """Merge all of L0 with L1 into a fresh L1 (newest versions win)."""
        self.compaction_count += 1
        merged: SortedDict = SortedDict()
        for sst in self.l1 + self.l0:  # oldest first; newer overwrite
            self.metrics.on_read("compaction", sst.size)
            for k, v in sst.items():
                merged[k] = v
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        new_l1 = SSTable.write(path, list(merged.items()), self.metrics,
                               "compaction", self.cache, sync=self.sync)
        for sst in self.l0 + self.l1:
            sst.delete()
        self.l0, self.l1 = [], [new_l1]

    # -------------------------------------------------------------- reads
    def get(self, key: bytes) -> Optional[bytes]:
        v = self.mem.get(key)
        if v is not None:
            return v
        for sst in reversed(self.l0):
            v = sst.get(key)
            if v is not None:
                return v
        for sst in self.l1:
            v = sst.get(key)
            if v is not None:
                return v
        return None

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        """Merged range scan [lo, hi]; newest version wins."""
        out: Dict[bytes, bytes] = {}
        for sst in self.l1:
            for k, v in sst.range(lo, hi):
                out[k] = v
        for sst in self.l0:
            for k, v in sst.range(lo, hi):
                out[k] = v
        i = self.mem.bisect_left(lo)
        j = self.mem.bisect_right(hi)
        for k in self.mem.keys()[i:j]:
            out[k] = self.mem[k]
        return sorted(out.items())

    def iterate_all(self) -> List[Tuple[bytes, bytes]]:
        return self.scan(b"", b"\xff" * 64)

    # ----------------------------------------------------------- recovery
    def recover(self) -> int:
        """Reload SSTs + replay WAL. Returns entries replayed.  Tolerates an
        empty-but-present WAL file (post-flush truncate leaves one)."""
        self.l0, self.l1 = [], []
        ssts = sorted(f for f in os.listdir(self.dir) if f.endswith(".sst"))
        for f in ssts:
            sst = SSTable.load(os.path.join(self.dir, f), self.metrics,
                               self.cache)
            self.metrics.on_read("recover_sst", sst.size)
            self.l0.append(sst)
        if ssts:  # never reuse a live SSTable filename after restart
            self._sst_seq = max(int(f[4:10]) for f in ssts) + 1
        n = 0
        if self.wal_enabled and os.path.exists(self._wal_path):
            with open(self._wal_path, "rb") as f:
                buf = f.read()
            if buf:
                self.metrics.on_read("recover_wal", len(buf))
            off = 0
            while off + _REC.size <= len(buf):
                klen, vlen = _REC.unpack_from(buf, off)
                if off + _REC.size + klen + vlen > len(buf):
                    break  # torn tail
                k = buf[off + _REC.size: off + _REC.size + klen]
                v = buf[off + _REC.size + klen: off + _REC.size + klen + vlen]
                self.mem[k] = v
                self.mem_bytes += klen + vlen
                off += _REC.size + klen + vlen
                n += 1
            if off < len(buf):
                # cut the torn tail NOW: post-restart appends land after it
                # on the "ab" handle, and a later replay would stop here and
                # silently lose them
                self._wal.truncate(off)
        return n

    def total_disk_bytes(self) -> int:
        return sum(s.size for s in self.l0 + self.l1)

    def close(self):
        if self._wal is not None:
            self._wal.close()   # flushes buffered records, no fsync (as seed)
        for sst in self.l0 + self.l1:
            sst.close()

    def destroy(self):
        self.close()
        for sst in self.l0 + self.l1:
            sst.delete()
        fs_remove(self._wal_path)
