"""MiniLSM — a real (if miniature) LSM-tree engine standing in for RocksDB.

Implements the pieces whose I/O the paper reasons about:
  * WAL (optional — PASV removes it),
  * sorted in-memory memtable with a size threshold,
  * SSTable flush (L0), leveled compaction L0 -> L1 (fanout-triggered),
  * point gets (memtable, then SSTs newest-first) and merged range scans.

All file traffic goes through Metrics with per-category tags so write
amplification from WAL/flush/compaction is separately visible.
"""
from __future__ import annotations

import os
import struct
from bisect import bisect_left, bisect_right
from typing import Dict, Iterator, List, Optional, Tuple

from sortedcontainers import SortedDict

from repro.core.metrics import Metrics

_REC = struct.Struct("<HI")  # key_len, val_len


class SSTable:
    def __init__(self, path: str, metrics: Metrics):
        self.path = path
        self.metrics = metrics
        self.keys: List[bytes] = []
        self.offsets: List[int] = []
        self.lengths: List[int] = []
        self.size = 0

    @staticmethod
    def write(path: str, items: List[Tuple[bytes, bytes]], metrics: Metrics,
              category: str) -> "SSTable":
        sst = SSTable(path, metrics)
        with open(path, "wb") as f:
            off = 0
            for k, v in items:
                rec = _REC.pack(len(k), len(v)) + k + v
                f.write(rec)
                sst.keys.append(k)
                sst.offsets.append(off)
                sst.lengths.append(len(rec))
                off += len(rec)
            sst.size = off
        metrics.on_write(category, sst.size)
        return sst

    @staticmethod
    def load(path: str, metrics: Metrics) -> "SSTable":
        sst = SSTable(path, metrics)
        with open(path, "rb") as f:
            buf = f.read()
        off = 0
        while off < len(buf):
            klen, vlen = _REC.unpack_from(buf, off)
            k = buf[off + _REC.size: off + _REC.size + klen]
            sst.keys.append(k)
            sst.offsets.append(off)
            sst.lengths.append(_REC.size + klen + vlen)
            off += _REC.size + klen + vlen
        sst.size = off
        return sst

    def get(self, key: bytes) -> Optional[bytes]:
        i = bisect_left(self.keys, key)
        if i >= len(self.keys) or self.keys[i] != key:
            return None
        with open(self.path, "rb") as f:
            f.seek(self.offsets[i])
            rec = f.read(self.lengths[i])
        self.metrics.on_read("sst_point", len(rec))
        klen, vlen = _REC.unpack_from(rec, 0)
        return rec[_REC.size + klen:_REC.size + klen + vlen]

    def range(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        i = bisect_left(self.keys, lo)
        j = bisect_right(self.keys, hi)
        if i >= j:
            return
        with open(self.path, "rb") as f:
            f.seek(self.offsets[i])
            buf = f.read(sum(self.lengths[i:j]))
        self.metrics.on_read("sst_range", len(buf))
        off = 0
        for _ in range(i, j):
            klen, vlen = _REC.unpack_from(buf, off)
            k = buf[off + _REC.size: off + _REC.size + klen]
            v = buf[off + _REC.size + klen: off + _REC.size + klen + vlen]
            yield k, v
            off += _REC.size + klen + vlen

    def items(self) -> Iterator[Tuple[bytes, bytes]]:
        yield from self.range(self.keys[0] if self.keys else b"",
                              self.keys[-1] if self.keys else b"")

    def delete(self):
        if os.path.exists(self.path):
            os.remove(self.path)


class MiniLSM:
    def __init__(self, dirpath: str, metrics: Metrics, *, wal: bool = True,
                 memtable_limit: int = 1 << 22, l0_limit: int = 4,
                 name: str = "lsm", sync: bool = False):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics
        self.wal_enabled = wal
        self.memtable_limit = memtable_limit
        self.l0_limit = l0_limit
        self.name = name
        self.sync = sync
        self.mem: SortedDict = SortedDict()
        self.mem_bytes = 0
        self.l0: List[SSTable] = []
        self.l1: List[SSTable] = []
        self._sst_seq = 0
        self._wal_path = os.path.join(dirpath, "wal.log")
        self._wal = open(self._wal_path, "ab") if wal else None
        self.compaction_count = 0

    # ------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes):
        if self._wal is not None:
            rec = _REC.pack(len(key), len(value)) + key + value
            self._wal.write(rec)
            if self.sync:
                self._wal.flush()
                os.fsync(self._wal.fileno())
                self.metrics.on_fsync()
            self.metrics.on_write("wal", len(rec))
        old = self.mem.get(key)
        self.mem[key] = value
        self.mem_bytes += len(key) + len(value) - \
            (len(key) + len(old) if old is not None else 0)
        if self.mem_bytes >= self.memtable_limit:
            self.flush()

    def flush(self):
        if not self.mem:
            return
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        self.l0.append(SSTable.write(path, list(self.mem.items()),
                                     self.metrics, "flush"))
        self.mem.clear()
        self.mem_bytes = 0
        if self._wal is not None:
            self._wal.close()
            self._wal = open(self._wal_path, "wb")  # truncate WAL
            self._wal.close()
            self._wal = open(self._wal_path, "ab")
        if len(self.l0) > self.l0_limit:
            self.compact()

    def compact(self):
        """Merge all of L0 with L1 into a fresh L1 (newest versions win)."""
        self.compaction_count += 1
        merged: SortedDict = SortedDict()
        for sst in self.l1 + self.l0:  # oldest first; newer overwrite
            self.metrics.on_read("compaction", sst.size)
            for k, v in sst.items():
                merged[k] = v
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        new_l1 = SSTable.write(path, list(merged.items()), self.metrics,
                               "compaction")
        for sst in self.l0 + self.l1:
            sst.delete()
        self.l0, self.l1 = [], [new_l1]

    # -------------------------------------------------------------- reads
    def get(self, key: bytes) -> Optional[bytes]:
        v = self.mem.get(key)
        if v is not None:
            return v
        for sst in reversed(self.l0):
            v = sst.get(key)
            if v is not None:
                return v
        for sst in self.l1:
            v = sst.get(key)
            if v is not None:
                return v
        return None

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        """Merged range scan [lo, hi]; newest version wins."""
        out: Dict[bytes, bytes] = {}
        for sst in self.l1:
            for k, v in sst.range(lo, hi):
                out[k] = v
        for sst in self.l0:
            for k, v in sst.range(lo, hi):
                out[k] = v
        i = self.mem.bisect_left(lo)
        j = self.mem.bisect_right(hi)
        for k in self.mem.keys()[i:j]:
            out[k] = self.mem[k]
        return sorted(out.items())

    def iterate_all(self) -> List[Tuple[bytes, bytes]]:
        return self.scan(b"", b"\xff" * 64)

    # ----------------------------------------------------------- recovery
    def recover(self) -> int:
        """Reload SSTs + replay WAL. Returns entries replayed."""
        self.l0, self.l1 = [], []
        ssts = sorted(f for f in os.listdir(self.dir) if f.endswith(".sst"))
        for f in ssts:
            sst = SSTable.load(os.path.join(self.dir, f), self.metrics)
            self.metrics.on_read("recover_sst", sst.size)
            self.l0.append(sst)
        n = 0
        if self.wal_enabled and os.path.exists(self._wal_path):
            with open(self._wal_path, "rb") as f:
                buf = f.read()
            self.metrics.on_read("recover_wal", len(buf))
            off = 0
            while off + _REC.size <= len(buf):
                klen, vlen = _REC.unpack_from(buf, off)
                if off + _REC.size + klen + vlen > len(buf):
                    break  # torn tail
                k = buf[off + _REC.size: off + _REC.size + klen]
                v = buf[off + _REC.size + klen: off + _REC.size + klen + vlen]
                self.mem[k] = v
                self.mem_bytes += klen + vlen
                off += _REC.size + klen + vlen
                n += 1
        return n

    def total_disk_bytes(self) -> int:
        return sum(s.size for s in self.l0 + self.l1)

    def close(self):
        if self._wal is not None:
            self._wal.close()

    def destroy(self):
        self.close()
        for sst in self.l0 + self.l1:
            sst.delete()
        if os.path.exists(self._wal_path):
            os.remove(self._wal_path)
