"""End-to-end operation tracing over the simulated cluster.

Three pieces, all virtual-time (SimNet ticks), all deterministic:

* **Tracer** — a span tree with cross-node context propagation.  A client
  op opens a root span; the span id rides on Raft/shipping messages
  (``AppendEntries``/``Reply``, ``InstallSnapshot``, ``TimeoutNow``, and
  the sealed-run ``rec`` dict for ``ShipRun``) so follower-side fsyncs,
  apply work, run adoption and GC steps reconstruct into one cross-node
  tree.  Every accounted I/O op (``Metrics.on_write/on_read/on_fsync``
  plus FaultFS rename/dir-fsync) is recorded as a child span carrying its
  layer tag (raft_log, wal, flush, valuelog, manifest, ship cursor, ...).
  Timestamps come exclusively from the injected ``clock`` (the cluster
  wires ``lambda: net.time``), so the serialized tree is a pure function
  of {seed, schedule}: same inputs => byte-identical ``to_json()``.

* **Causality auditor** — ``audit(tracer.events)`` replays the protocol
  event stream and reports structural violations: a follower acking an
  append it never made durable, a leader committing without a quorum of
  recorded acks, a node applying past its known commit index, a client
  acked before the leader applied.  Zero violations is a smoke gate.

* **MetricsRegistry** — a labeled counter/gauge/histogram registry with
  Prometheus-style text exposition and a JSON scrape, the typed surface
  that ``Metrics.fill_registry`` and ``Cluster.health_report`` publish
  through instead of ad-hoc dict keys.

The tracer is installed process-globally (same pattern as
``faultfs.install``): hot paths pay one ``_ACTIVE is None`` check when
tracing is off, and installing/uninstalling never perturbs the
simulation (no RNG draws, no virtual-time advances).
"""

from __future__ import annotations

import json
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# --------------------------------------------------------------- spans


class Span:
    """One node-local unit of work.  ``parent == 0`` means root."""

    __slots__ = ("sid", "parent", "name", "kind", "node", "t0", "t1", "tags")

    def __init__(self, sid: int, parent: int, name: str, kind: str,
                 node: Optional[int], t0: int,
                 tags: Optional[Dict[str, Any]] = None):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.kind = kind
        self.node = node
        self.t0 = t0
        self.t1: Optional[int] = None
        self.tags: Dict[str, Any] = tags or {}

    def to_dict(self) -> Dict[str, Any]:
        return {"sid": self.sid, "parent": self.parent, "name": self.name,
                "kind": self.kind, "node": self.node,
                "t0": self.t0, "t1": self.t1, "tags": self.tags}


class _SpanCtx:
    __slots__ = ("_t", "_name", "_kw", "_sid")

    def __init__(self, tracer: "Tracer", name: str, kw: Dict[str, Any]):
        self._t = tracer
        self._name = name
        self._kw = kw

    def __enter__(self) -> int:
        self._sid = self._t.begin(self._name, **self._kw)
        return self._sid

    def __exit__(self, *exc) -> None:
        self._t.end(self._sid)


class Tracer:
    """Virtual-time span tracer.

    ``clock`` must be a zero-arg callable returning the current virtual
    time (the cluster passes ``lambda: net.time``).  The simulation is
    single-threaded and message handlers run to completion, so one
    global span stack is sufficient: whatever span is on top when an
    I/O hook fires is, by construction, the work that caused it.
    """

    def __init__(self, clock: Optional[Callable[[], int]] = None):
        self.clock: Callable[[], int] = clock or (lambda: 0)
        self.spans: List[Span] = []
        self.events: List[Dict[str, Any]] = []      # causality audit stream
        self.net_events: List[Tuple] = []           # unified SimNet feed
        self._by_id: Dict[int, Span] = {}
        self._stack: List[int] = []
        self._next = 1
        # (raft group, raft index) -> span id.  Multi-Raft: the same raft
        # index exists independently in every shard group, so the context
        # registry must be keyed by group too (group None = ungrouped).
        self._index_ctx: Dict[Tuple[Optional[int], int], int] = {}

    # ---------------------------------------------------- span lifecycle

    def begin(self, name: str, *, kind: str = "span",
              node: Optional[int] = None,
              parent: Optional[int] = None, **tags: Any) -> int:
        """Open a span and push it on the stack.  ``parent=None`` nests
        under the current top of stack; pass an explicit id (e.g. a ctx
        carried on a message) to graft a remote child."""
        sid = self._next
        self._next += 1
        if parent is None:
            pid = self._stack[-1] if self._stack else 0
        else:
            pid = parent
        sp = Span(sid, pid, name, kind, node, self.clock(), tags or None)
        self._by_id[sid] = sp
        self.spans.append(sp)
        self._stack.append(sid)
        return sid

    def end(self, sid: int) -> None:
        sp = self._by_id.get(sid)
        if sp is not None and sp.t1 is None:
            sp.t1 = self.clock()
        if self._stack and self._stack[-1] == sid:
            self._stack.pop()
        elif sid in self._stack:                    # tolerate interleaving
            self._stack.remove(sid)

    def span(self, name: str, **kw: Any) -> _SpanCtx:
        return _SpanCtx(self, name, kw)

    def current(self) -> int:
        """Span id to stamp into an outgoing message (0 = no context)."""
        return self._stack[-1] if self._stack else 0

    def enter(self, sid: int) -> None:
        """Re-enter an already-open span: make it the current context
        (stack top) without opening a new one.  Used by the sharded
        client to interleave work across per-shard subtrees — submits for
        shard A nest under A's span even while B's span is also open.
        Pair with exit(); end() still closes the span exactly once."""
        self._stack.append(sid)

    def exit(self, sid: int) -> None:
        """Leave a span re-entered via enter() without closing it."""
        if self._stack and self._stack[-1] == sid:
            self._stack.pop()
        elif sid in self._stack:                    # tolerate interleaving
            self._stack.remove(sid)

    def tag(self, sid: int, **tags: Any) -> None:
        sp = self._by_id.get(sid)
        if sp is not None:
            sp.tags.update(tags)

    # ------------------------------------------- cross-node propagation

    def register_index(self, index: int, sid: Optional[int] = None,
                       group: Optional[int] = None) -> None:
        """Remember which span originated the op at raft ``index`` (in
        shard ``group``, None = ungrouped) so a later AppendEntries batch
        can carry that context."""
        sid = self.current() if sid is None else sid
        if sid:
            self._index_ctx[(group, index)] = sid

    def ctx_for_range(self, lo: int, hi: int,
                      group: Optional[int] = None) -> int:
        """Newest registered context in [lo, hi] of ``group`` (0 if none
        — e.g. a no-op barrier or config entry that no client op
        originated)."""
        for i in range(hi, lo - 1, -1):
            sid = self._index_ctx.get((group, i))
            if sid:
                return sid
        return 0

    # ------------------------------------------------------ I/O records

    def io(self, op: str, category: str, nbytes: int,
           node: Optional[int] = None) -> None:
        """Record one I/O op as a zero-duration child of the current
        span (or as a root-level span when no span is active, so traced
        I/O always reconciles exactly with the ``Metrics`` counters)."""
        parent = self._stack[-1] if self._stack else 0
        if node is None and parent:
            node = self._by_id[parent].node
        sid = self._next
        self._next += 1
        t = self.clock()
        sp = Span(sid, parent, "io." + op, "io", node, t,
                  {"category": category, "bytes": nbytes})
        sp.t1 = t
        self._by_id[sid] = sp
        self.spans.append(sp)

    # ------------------------------------------------------ audit stream

    def event(self, kind: str, node: int, index: int, **extra: Any) -> None:
        ev = {"t": self.clock(), "kind": kind, "node": node, "index": index}
        if extra:
            ev.update(extra)
        self.events.append(ev)

    def net_event(self, kind: str, t: int, src: int, dst: int,
                  msg_type: str, reason: Optional[str] = None) -> None:
        self.net_events.append((kind, t, src, dst, msg_type, reason))

    # ----------------------------------------------------------- export

    def export(self) -> Dict[str, Any]:
        """Serializable dump.  A span whose parent id is unknown (its
        context crossed a tracer swap, or the originating tracer was
        uninstalled mid-flight) is flagged ``orphan`` — kept, never
        silently dropped."""
        ids = self._by_id
        spans = []
        for sp in self.spans:
            d = sp.to_dict()
            if sp.parent and sp.parent not in ids:
                d["orphan"] = True
            spans.append(d)
        return {"spans": spans, "events": self.events,
                "net_events": [list(e) for e in self.net_events]}

    def to_json(self) -> str:
        return json.dumps(self.export(), sort_keys=True,
                          separators=(",", ":"))

    # ------------------------------------------------------- convenience

    def children(self, sid: int) -> List[Span]:
        return [s for s in self.spans if s.parent == sid]

    def roots(self, name: Optional[str] = None) -> List[Span]:
        return [s for s in self.spans
                if s.parent == 0 and (name is None or s.name == name)]

    def subtree(self, sid: int) -> List[Span]:
        """All spans under ``sid`` (excluding it), depth-first."""
        out: List[Span] = []
        frontier = [sid]
        kids: Dict[int, List[Span]] = {}
        for s in self.spans:
            kids.setdefault(s.parent, []).append(s)
        while frontier:
            nid = frontier.pop()
            for s in kids.get(nid, ()):
                out.append(s)
                frontier.append(s.sid)
        return out

    def io_sums(self, sid: Optional[int] = None
                ) -> Dict[Tuple[str, str], int]:
        """Sum of io-span bytes keyed by (op, category); over the whole
        trace, or over one span's subtree when ``sid`` is given."""
        spans = self.subtree(sid) if sid is not None else self.spans
        out: Dict[Tuple[str, str], int] = {}
        for s in spans:
            if s.kind != "io":
                continue
            k = (s.name[3:], s.tags.get("category", "?"))
            out[k] = out.get(k, 0) + int(s.tags.get("bytes", 0))
        return out


# ----------------------------------------------------- global installer

_ACTIVE: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[Tracer]:
    return _ACTIVE


# ------------------------------------------------------ causality audit


def audit(events: Iterable[Dict[str, Any]]) -> List[str]:
    """Replay a tracer's event stream and return structural violations.

    Checks (per node ``n``, raft index ``i``):

    * ``ack_sent``   — n acked an append it has not made durable
                       (durable-before-ack: ``commit_window`` precedes
                       every success reply);
    * ``commit``     — the leader advanced commit_index without a quorum
                       of recorded acks (its own durability counts);
    * ``apply``      — n applied past its recorded commit knowledge;
    * ``client_ack`` — the client was acked before the serving leader
                       applied the op's index.

    "Durable" here is the protocol point (``commit_window`` was invoked
    before the ack), which is what the paper's durable-before-ack
    argument needs; whether the window physically fsynced is the
    ``sync=`` knob, audited separately by the crash-point sweeps.
    """
    violations: List[str] = []
    durable: Dict[int, int] = {}      # node -> max durable log index
    committed: Dict[int, int] = {}    # node -> max known commit index
    applied: Dict[int, int] = {}      # node -> max applied index
    acked: Dict[int, Dict[int, int]] = {}  # leader -> {peer -> max match}
    for ev in events:
        k = ev["kind"]
        n = ev["node"]
        i = ev["index"]
        if k == "durable":
            durable[n] = max(durable.get(n, 0), i)
        elif k == "ack_sent":
            if durable.get(n, 0) < i:
                violations.append(
                    "t=%s node %s acked index %s before durable "
                    "(durable=%s)" % (ev["t"], n, i, durable.get(n, 0)))
        elif k == "ack_recv":
            peers = acked.setdefault(n, {})
            f = ev.get("from", -1)
            peers[f] = max(peers.get(f, 0), i)
        elif k == "commit":
            voters = ev.get("voters", [n])
            need = len(voters) // 2 + 1
            have = 0
            for v in voters:
                if v == n:
                    if durable.get(n, 0) >= i:
                        have += 1
                elif acked.get(n, {}).get(v, 0) >= i:
                    have += 1
            if have < need:
                violations.append(
                    "t=%s node %s committed index %s before quorum ack "
                    "(%d/%d of voters %s)"
                    % (ev["t"], n, i, have, need, sorted(voters)))
            committed[n] = max(committed.get(n, 0), i)
        elif k == "commit_learned":
            committed[n] = max(committed.get(n, 0), i)
        elif k == "snapshot_install":
            # an installed snapshot is durable, committed and applied
            # state by definition (it was built from applied state on
            # the leader and persisted before the reply)
            durable[n] = max(durable.get(n, 0), i)
            committed[n] = max(committed.get(n, 0), i)
            applied[n] = max(applied.get(n, 0), i)
        elif k == "apply":
            if committed.get(n, 0) < i:
                violations.append(
                    "t=%s node %s applied index %s before commit "
                    "(known commit=%s)" % (ev["t"], n, i,
                                           committed.get(n, 0)))
            applied[n] = max(applied.get(n, 0), i)
        elif k == "client_ack":
            if applied.get(n, 0) < i:
                violations.append(
                    "t=%s client acked index %s on node %s before apply "
                    "(applied=%s)" % (ev["t"], i, n, applied.get(n, 0)))
        # unknown kinds (e.g. "fault" markers, "recover") are annotations
    return violations


# ------------------------------------------------------ waterfall render


def render_waterfall(tracer: Tracer, sid: int, tick_us: float = 50.0,
                     ) -> str:
    """ASCII waterfall of one span subtree, for humans.

    Each line: virtual-time offset, node, span name, duration, and
    (for io spans) the layer tag + bytes.
    """
    root = tracer._by_id.get(sid)
    if root is None:
        return "<no such span %d>" % sid
    kids: Dict[int, List[Span]] = {}
    for s in tracer.spans:
        kids.setdefault(s.parent, []).append(s)
    lines: List[str] = []

    def walk(sp: Span, depth: int) -> None:
        dt = sp.t0 - root.t0
        dur = (sp.t1 - sp.t0) if sp.t1 is not None else 0
        node = "node%s" % sp.node if sp.node is not None else "client"
        extra = ""
        if sp.kind == "io":
            extra = "  [%s %dB]" % (sp.tags.get("category", "?"),
                                    sp.tags.get("bytes", 0))
        elif sp.tags:
            extra = "  " + ";".join("%s=%s" % (k, v)
                                    for k, v in sorted(sp.tags.items()))
        lines.append("%+8.1fus  %-7s %s%-24s %6.1fus%s"
                     % (dt * tick_us, node, "  " * depth, sp.name,
                        dur * tick_us, extra))
        for ch in sorted(kids.get(sp.sid, ()), key=lambda s: (s.t0, s.sid)):
            walk(ch, depth + 1)

    walk(root, 0)
    return "\n".join(lines)


# ------------------------------------------------------ metrics registry


class _Child:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = v


class _HistChild:
    __slots__ = ("hist",)

    def __init__(self) -> None:
        from repro.core.metrics import LatencyHistogram  # lazy: no cycle
        self.hist = LatencyHistogram()

    def observe(self, v: float) -> None:
        self.hist.record(v)


class _Family:
    def __init__(self, kind: str, name: str, help: str,
                 labelnames: Tuple[str, ...]):
        self.kind = kind
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], Any] = {}

    def labels(self, **kw: Any):
        if set(kw) != set(self.labelnames):
            raise ValueError(
                "metric %s takes labels %r, got %r"
                % (self.name, self.labelnames, tuple(sorted(kw))))
        key = tuple(str(kw[k]) for k in self.labelnames)
        ch = self._children.get(key)
        if ch is None:
            ch = _HistChild() if self.kind == "histogram" else _Child()
            self._children[key] = ch
        return ch

    # bare-metric convenience: no labels declared
    def inc(self, n: float = 1) -> None:
        self.labels().inc(n)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)


class MetricsRegistry:
    """Labeled counter/gauge/histogram families with Prometheus-style
    text exposition and a JSON scrape.  Deterministic output: families
    and label sets are emitted sorted."""

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}

    def _family(self, kind: str, name: str, help: str,
                labelnames: Iterable[str]) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(kind, name, help, tuple(labelnames))
            self._families[name] = fam
        elif fam.kind != kind or fam.labelnames != tuple(labelnames):
            raise ValueError(
                "metric %s re-registered as %s%r (was %s%r)"
                % (name, kind, tuple(labelnames), fam.kind, fam.labelnames))
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> _Family:
        return self._family("counter", name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> _Family:
        return self._family("gauge", name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = ()) -> _Family:
        return self._family("histogram", name, help, labelnames)

    @staticmethod
    def _fmt_value(v: float) -> str:
        if isinstance(v, bool):
            return "1" if v else "0"
        if float(v).is_integer():
            return str(int(v))
        return repr(float(v))

    def prometheus_text(self) -> str:
        out: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                out.append("# HELP %s %s" % (name, fam.help))
            ptype = "summary" if fam.kind == "histogram" else fam.kind
            out.append("# TYPE %s %s" % (name, ptype))
            for key in sorted(fam._children):
                ch = fam._children[key]
                base = dict(zip(fam.labelnames, key))

                def series(metric: str, labels: Dict[str, str],
                           value: float) -> str:
                    if labels:
                        lbl = "{%s}" % ",".join(
                            '%s="%s"' % (k, labels[k])
                            for k in sorted(labels))
                    else:
                        lbl = ""
                    return "%s%s %s" % (metric, lbl, self._fmt_value(value))

                if fam.kind == "histogram":
                    h = ch.hist
                    out.append(series(name + "_count", base, h.n))
                    out.append(series(name + "_sum", base, h.total))
                    if h.n:
                        for q in (0.5, 0.99):
                            out.append(series(
                                name, dict(base, quantile=str(q)),
                                h.quantile(q)))
                else:
                    out.append(series(name, base, ch.value))
        return "\n".join(out) + "\n"

    def scrape(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for key in sorted(fam._children):
                ch = fam._children[key]
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    h = ch.hist
                    samples.append({"labels": labels, "count": h.n,
                                    "sum": h.total,
                                    "p50": h.quantile(0.5) if h.n else 0.0,
                                    "p99": h.quantile(0.99) if h.n else 0.0})
                else:
                    samples.append({"labels": labels, "value": ch.value})
            out[name] = {"type": fam.kind, "help": fam.help,
                         "samples": samples}
        return out
