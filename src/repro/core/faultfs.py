"""FaultFS — the injectable I/O shim every persistence site routes through.

Every crash the chaos harness could inject before this module was a POLITE
one: the node was killed *between* operations, after every buffered write
had landed.  Real stores lose acked data to the other kind — kill -9 in
the middle of an fsync, a torn sector, a rename whose directory entry
never reached the platter.  FaultFS makes that kind enumerable:

  * Every mutating I/O op (write / truncate / fsync / replace / remove /
    dirsync) is numbered in program order.  A recording run yields the
    sweep domain; `arm(after=k)` kills the process state at op *k*.
  * Two views per file.  The VOLATILE view is the real file on disk —
    wrapped handles are raw (unbuffered) and write through, so a handle
    abandoned without close() can never flush anything later.  The
    DURABLE view is a per-file shadow advanced only by fsync.
  * Crash = `SimulatedCrash` raised *before* op k executes (kill -9: the
    op never happens).  `materialize(scope)` then rewrites every file
    under the crashed node's directory to its durable view:
      drop         unsynced bytes vanish entirely,
      torn         a deterministic sector-aligned prefix of the unsynced
                   tail survives (crash mid-fsync),
      lost_rename  drop + any os.replace whose parent directory was not
                   fsynced afterwards is undone (dst reverts, src
                   reappears with its durable content).
    Files that never existed durably are removed.  All of it is a pure
    function of {seed, crash op index, mode} — a sweep record replays.

SimulatedCrash subclasses BaseException so a stray `except Exception`
recovery helper cannot swallow a kill -9 and keep the "dead" node running.

When no FaultFS is installed the module-level helpers are exact
pass-throughs (plain buffered open / os.fsync / os.replace), so the hot
path pays nothing.  `write_json_atomic` is the one behavioral export: the
audited metadata-commit pattern (tmp -> fsync(tmp) -> rename -> fsync of
the parent directory) used by every manifest/state/meta file — skipping
the tmp fsync can surface an empty file *after* the rename, skipping the
dirsync can lose the rename itself.
"""
from __future__ import annotations

import builtins
import json
import os
import random
from typing import Dict, List, Optional

from repro.core import trace as _trace

MODES = ("drop", "torn", "lost_rename")


class SimulatedCrash(BaseException):
    """kill -9 at a numbered I/O op.  BaseException on purpose: broad
    `except Exception` clauses in recovery helpers must not swallow it."""

    def __init__(self, op_index: int, kind: str, path: str):
        super().__init__(
            f"simulated kill -9 at io op {op_index} ({kind} {path})")
        self.op_index = op_index
        self.kind = kind
        self.path = path


_ACTIVE: Optional["FaultFS"] = None


def active() -> Optional["FaultFS"]:
    return _ACTIVE


def install(fs: "FaultFS") -> "FaultFS":
    global _ACTIVE
    _ACTIVE = fs
    return fs


def uninstall():
    global _ACTIVE
    _ACTIVE = None


def _under(path: str, scope: str) -> bool:
    """Prefix scope match; a scope ending in os.sep binds to a directory
    (so node1/ can never match node10), otherwise it is a filename-stem
    prefix (…/valuelog matches valuelog_m0003.log)."""
    if not scope:
        return True
    return path.startswith(scope)


def _norm_scope(scope: str) -> str:
    """abspath that PRESERVES a trailing os.sep (abspath strips it, which
    would turn a directory-bound scope back into a stem prefix)."""
    if not scope:
        return ""
    bound = scope.endswith(os.sep)
    scope = os.path.abspath(scope)
    return scope + os.sep if bound else scope


class FaultFS:
    """One crash experiment: op numbering + shadow tracking + the armed
    crash point.  Install via faultfs.install(); every fs_* helper then
    routes through this instance."""

    def __init__(self, seed: int = 0, sector: int = 128):
        self.seed = seed
        self.sector = sector
        self.op_count = 0
        self.ops_by_kind: Dict[str, int] = {}
        # durable view per abspath: bytes, or None = durably absent
        self._durable: Dict[str, Optional[bytes]] = {}
        # renames not yet covered by a parent-directory fsync
        self._renames: List[dict] = []
        self._armed: Optional[dict] = None
        self._crash_mode = "drop"
        self.last_crash: Optional[SimulatedCrash] = None
        # live wrapped handles: kill -9 takes the fds with it, so
        # materialize() force-closes handles under its scope (and long
        # sweeps abandoning crashed engines leak no descriptors)
        self._open_files: List["_FaultFile"] = []
        self.injected = {"crashes": 0, "dropped_bytes": 0,
                         "torn_tails": 0, "lost_renames": 0}

    # ------------------------------------------------------------ arming
    def arm(self, after: int, *, scope: str = "", mode: str = "drop"):
        """Let `after` more ops under `scope` complete, then crash on the
        next one with `mode` semantics.  Single-shot: disarms on fire."""
        if mode not in MODES:
            raise ValueError(f"unknown fault mode {mode!r}")
        self._armed = {"left": after,
                       "scope": _norm_scope(scope),
                       "mode": mode}

    def disarm(self):
        self._armed = None

    @property
    def armed(self) -> bool:
        return self._armed is not None

    def _op(self, kind: str, path: str) -> int:
        idx = self.op_count
        self.op_count += 1
        self.ops_by_kind[kind] = self.ops_by_kind.get(kind, 0) + 1
        a = self._armed
        if a is not None and _under(path, a["scope"]):
            if a["left"] <= 0:
                self._armed = None
                self._crash_mode = a["mode"]
                self.injected["crashes"] += 1
                self.last_crash = SimulatedCrash(idx, kind, path)
                raise self.last_crash
            a["left"] -= 1
        return idx

    # ---------------------------------------------------------- tracking
    def _baseline(self, path: str):
        """First sighting of a file: whatever is on disk is durable (the
        previous crash/boot already settled it)."""
        if path not in self._durable:
            if os.path.exists(path):
                with builtins.open(path, "rb") as f:
                    self._durable[path] = f.read()
            else:
                self._durable[path] = None

    # -------------------------------------------------------- operations
    def open(self, path: str, mode: str) -> "_FaultFile":
        path = os.path.abspath(path)
        self._baseline(path)
        return _FaultFile(self, path, mode)

    def fsync(self, target):
        """fsync a wrapped file or a path: volatile view becomes durable.
        No real os.fsync is issued — the crash is simulated, the shadow is
        the platter."""
        path = target if isinstance(target, str) else target.path
        path = os.path.abspath(path)
        self._op("fsync", path)
        if os.path.exists(path):
            with builtins.open(path, "rb") as f:
                self._durable[path] = f.read()
        else:
            self._durable[path] = None

    def replace(self, src: str, dst: str):
        src, dst = os.path.abspath(src), os.path.abspath(dst)
        self._op("replace", dst)
        self._baseline(src)
        self._baseline(dst)
        self._renames.append({"dir": os.path.dirname(dst),
                              "src": src, "dst": dst,
                              "src_durable": self._durable.get(src),
                              "dst_durable": self._durable.get(dst)})
        os.replace(src, dst)
        # the rename carries src's INODE: dst's durable content is whatever
        # of src was synced (maybe nothing — the classic missing-tmp-fsync)
        self._durable[dst] = self._durable.get(src)
        self._durable[src] = None

    def remove(self, path: str):
        path = os.path.abspath(path)
        self._op("remove", path)
        if os.path.exists(path):
            os.remove(path)
        self._durable[path] = None   # unlink modeled as immediately durable

    def dirsync(self, dirpath: str):
        """Parent-directory fsync: pending renames under it become
        durable (can no longer be lost)."""
        d = os.path.abspath(dirpath)
        self._op("dirsync", os.path.join(d, ""))
        self._renames = [r for r in self._renames if r["dir"] != d]

    def truncate(self, path: str, size: int):
        path = os.path.abspath(path)
        self._op("truncate", path)

    # ----------------------------------------------------------- crashes
    def materialize(self, scope: str = "", mode: Optional[str] = None) -> int:
        """Apply kill -9 to every tracked file under `scope`: rewrite the
        on-disk (volatile) state to the durable view, mode-adjusted; undo
        un-dirsynced renames in lost_rename mode; reset tracking for the
        scope so recovery re-baselines from the crash state.  Returns the
        number of files changed.  Deterministic from
        {seed, crash op index, mode}."""
        scope = _norm_scope(scope)
        mode = mode or self._crash_mode
        at = self.last_crash.op_index if self.last_crash else self.op_count
        rng = random.Random(f"faultfs:{self.seed}:{at}:{mode}")
        changed = 0
        for fh in [fh for fh in self._open_files if _under(fh.path, scope)]:
            fh.close()               # the dead process's fds go with it
        if mode == "lost_rename":
            undo = [r for r in self._renames if _under(r["dst"], scope)]
            for r in reversed(undo):
                self._write_state(r["dst"], r["dst_durable"])
                self._durable[r["dst"]] = r["dst_durable"]
                if r["src_durable"] is not None:
                    self._write_state(r["src"], r["src_durable"])
                    self._durable[r["src"]] = r["src_durable"]
                self.injected["lost_renames"] += 1
                changed += 1
        for path in sorted(p for p in self._durable if _under(p, scope)):
            durable = self._durable[path]
            current: Optional[bytes] = None
            if os.path.exists(path):
                with builtins.open(path, "rb") as f:
                    current = f.read()
            target = durable
            if mode == "torn" and current is not None:
                base = durable if durable is not None else b""
                if current[:len(base)] == base and len(current) > len(base):
                    extra = current[len(base):]
                    nsec = -(-len(extra) // self.sector)
                    keep = min(len(extra),
                               rng.randrange(nsec + 1) * self.sector)
                    if keep:
                        target = base + extra[:keep]
                        self.injected["torn_tails"] += 1
            if target != current:
                self._write_state(path, target)
                self.injected["dropped_bytes"] += max(
                    0, len(current or b"") - len(target or b""))
                changed += 1
        self._durable = {p: v for p, v in self._durable.items()
                         if not _under(p, scope)}
        self._renames = [r for r in self._renames
                         if not _under(r["dst"], scope)]
        self._armed = None
        return changed

    @staticmethod
    def _write_state(path: str, data: Optional[bytes]):
        """Set the raw on-disk state (bypasses op counting/tracking)."""
        if data is None:
            if os.path.exists(path):
                os.remove(path)
        else:
            with builtins.open(path, "wb") as f:
                f.write(data)

    def counters(self) -> dict:
        return {"io_ops": self.op_count, **self.injected}


class _FaultFile:
    """Write-through wrapper: a raw (unbuffered) handle, so the volatile
    view IS the file on disk and dropping the handle without close() —
    kill -9 — can never flush anything afterwards.  Mutations are
    numbered/armed through the owning FaultFS."""

    def __init__(self, fs: FaultFS, path: str, mode: str):
        if "b" not in mode:
            raise ValueError(f"FaultFS wraps binary files only, got {mode!r}")
        self.fs = fs
        self.path = path
        self._raw = builtins.open(path, mode, buffering=0)
        fs._open_files.append(self)

    def write(self, data) -> int:
        if data:
            self.fs._op("write", self.path)
            self._raw.write(data)
        return len(data)

    def truncate(self, size: Optional[int] = None) -> int:
        if size is None:
            size = self._raw.tell()
        self.fs.truncate(self.path, size)
        return self._raw.truncate(size)

    def read(self, n: int = -1) -> bytes:
        return self._raw.read(n)

    def seek(self, pos: int, whence: int = 0) -> int:
        return self._raw.seek(pos, whence)

    def tell(self) -> int:
        return self._raw.tell()

    def fileno(self) -> int:
        return self._raw.fileno()

    def flush(self):
        pass          # raw handle: every write already landed

    def close(self):
        if self in self.fs._open_files:
            self.fs._open_files.remove(self)
        self._raw.close()

    @property
    def closed(self) -> bool:
        return self._raw.closed

    def __enter__(self) -> "_FaultFile":
        return self

    def __exit__(self, *exc):
        self.close()


# ------------------------------------------------------------- pass-through
def fs_open(path: str, mode: str = "rb"):
    """open() for persistence sites.  Read-only handles are never wrapped
    (reads see the volatile view either way)."""
    if _ACTIVE is None or not any(c in mode for c in "wa+"):
        return builtins.open(path, mode)
    return _ACTIVE.open(path, mode)


def fs_fsync(f):
    """fsync an open (wrapped or plain) file."""
    if isinstance(f, _FaultFile):
        f.fs.fsync(f)
    else:
        f.flush()
        os.fsync(f.fileno())


def fs_fsync_path(path: str):
    """fsync a file by path (e.g. sealed run data before its meta commits)."""
    if _trace._ACTIVE is not None:
        _trace._ACTIVE.io("fsync_path", os.path.basename(path), 0)
    if _ACTIVE is not None:
        _ACTIVE.fsync(path)
        return
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fs_replace(src: str, dst: str):
    if _trace._ACTIVE is not None:
        _trace._ACTIVE.io("rename", os.path.basename(dst), 0)
    if _ACTIVE is not None:
        _ACTIVE.replace(src, dst)
    else:
        os.replace(src, dst)


def fs_remove(path: str):
    if _ACTIVE is not None:
        _ACTIVE.remove(path)
    elif os.path.exists(path):
        os.remove(path)


def fs_dirsync(dirpath: str):
    """fsync a directory: makes renames/creations inside it durable."""
    if _trace._ACTIVE is not None:
        _trace._ACTIVE.io("dirsync", os.path.basename(dirpath) or ".", 0)
    if _ACTIVE is not None:
        _ACTIVE.dirsync(dirpath)
        return
    fd = os.open(dirpath, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_atomic(path: str, obj):
    """The audited metadata-commit pattern, used by runs_manifest.json,
    gc_state.json, raft_meta.json and every run .meta file:

        write tmp -> fsync(tmp) -> os.replace -> fsync(parent dir)

    fsyncing the tmp file prevents the rename from exposing an empty or
    torn file; fsyncing the parent directory prevents the rename itself
    from being lost (FaultFS's lost_rename mode exercises exactly these
    two omissions).  Byte accounting stays with the caller."""
    tmp = path + ".tmp"
    f = fs_open(tmp, "wb")
    try:
        f.write(json.dumps(obj).encode())
        fs_fsync(f)
    finally:
        f.close()
    fs_replace(tmp, path)
    fs_dirsync(os.path.dirname(path) or ".")
