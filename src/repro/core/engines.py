"""Storage engines: Nezha and every baseline the paper compares against.

Each engine is simultaneously (a) the Raft log store (persistence of log
entries) and (b) the replicated state machine (apply on commit), matching how
the paper couples/decouples the two layers:

  Original    raft log (full values) + LSM[WAL -> memtable -> SST -> compact]
              => value written >= 3x                      [paper baseline]
  PASV        Original minus the storage-engine WAL (FAST'22)   => >= 2x
  Dwisckey    Original raft log + WiscKey engine (value log below the LSM)
              => 2x value writes, scattered scan reads
  LSM-Raft    Original on the leader; followers skip WAL and receive shipped
              compacted SSTs instead of re-compacting (SIGMOD'25)
  Nezha-NoGC  KVS-Raft: raft log IS the ValueLog, LSM holds key->offset
              => exactly 1x value write; reads pay indirection
  Nezha       Nezha-NoGC + Raft-aware GC (sorted ValueLog + hash index) +
              three-phase request routing; with run_shipping=True, GC is
              leader-only and followers adopt the sealed runs (below)

Replication tiers — how bytes reach a follower, cheapest-first:

  1. Value shipping (always on): AppendEntries carries the log entries
     themselves; each follower persists them once into its own active
     segment.  This is the only tier that runs on the put critical path.
  2. Run shipping (NezhaEngine, DEFAULT — opt out with
     run_shipping=False): only the leader runs GC flushes and leveled
     merges; every sealed run is streamed to followers as a chunked,
     resumable run-adoption record (shipping.py) and installed wholesale —
     follower gc_sorted/gc_level_merge rewrite bytes stay at zero.  Fires
     whenever the leader seals a run, strictly ordered behind the applied
     log.  On by default since it soaked through the PR-4 chaos suite;
     the opt-out exists for A/B baselines (fig_runship's 'local' mode)
     and for standalone-engine tests that exercise local GC on every
     node.
  3. Snapshot shipping (always available): InstallSnapshot ships the whole
     run set.  Fires when a follower is behind the leader's log-compaction
     point (classic Raft catch-up) or when a run-adoption fence trips (a
     diverged / crashed / long-partitioned follower), making it run
     shipping's safety net.

  LSM-Raft's `_ShippedLSM` is the related-work variant of tier 2: shipped
  compacted SSTables instead of shipped value-log runs.

Read tiers mirror the replication tiers (repro/core/client.py): the
cluster's client API serves LINEARIZABLE reads via ReadIndex on the leader
(one heartbeat-quorum round covers a batch of reads), LEASE reads locally
on a leader holding a heartbeat-renewed lease (zero quorum rounds), and
SESSION reads from ANY node gated by a last-seen-index session token.
Run shipping is what makes the SESSION tier pay off: followers hold the
leader's exact sealed-run sets, so follower scans are byte-equal with the
leader and scan capacity scales with cluster size instead of serializing
through one node (benchmarks/fig_reads.py).

Batching / caching knobs (the group-commit I/O pipeline):

  max_batch (RaftNode/Cluster, default 64)
      Entries shipped per AppendEntries RPC AND the group-commit window:
      client_put_many persists a whole window with one buffered write, and
      commit_window() turns it into ONE fsync (per store) instead of one
      per record.  benchmarks/fig12_batching.py sweeps this knob.
  commit window (LogStoreBase.commit_window)
      Invoked by Raft at batch boundaries: after client_put/client_put_many
      on the leader, after the follower appends an AppendEntries batch
      (before acking), and after each _apply_committed drain.  Engines
      flush+fsync every dirty file exactly once per call.
  cache_bytes (EngineBase, default 2 MiB)
      Byte budget of the per-engine BlockCache shared by SSTable blocks,
      SortedStore point records, and ValueLog offset reads.  Per-SSTable
      bloom filters (cache-independent) skip files on point gets.

Durability contract (enforced by the FaultFS crash-point sweep,
tests/test_crashpoints.py — kill -9 at ANY numbered I/O op):

  Survives, at every crash point (sync=True):
    * every ACKED write — an entry is fsynced into the value log by
      commit_window() BEFORE Raft acks/commits it (raft.py), so the acked
      prefix of the log is always on disk; recovery replays it through the
      header-only scan.
    * the manifest epoch / run set — runs_manifest.json, gc_state.json,
      every run .meta and raft_meta.json commit via
      faultfs.write_json_atomic (tmp write -> fsync(tmp) -> rename ->
      fsync(parent dir)); run DATA files are fsynced before their meta
      declares them complete; retired files are deleted only after the
      manifest swap is fully durable.
    * the ship cursor — ship_pos rides in the manifest, same swap.
    * the membership config — the newest KIND_CONFIG entry a node has
      ADOPTED (effective on append) rides in raft_meta.json next to
      term/vote, and the entry itself is in the fsynced value log.  What
      a LEARNER persists before promotion is exactly a voter's state: the
      adopted run set + manifest (its catch-up arrived as InstallSnapshot
      + shipped runs, both durable via the manifest swap), the applied
      log tail in its own value log, and the raft meta including the
      config that added it.  Promotion adds no new durability class —
      the promote entry is just another config commit under the widened
      quorum, so a learner crashing at ANY point before/after promotion
      recovers to a state the leader can resume shipping to (ship_pos
      cursor) without re-running GC.

  May legally be lost:
    * the unacked tail — value-log bytes past the last fsync (dropped or
      torn at a sector boundary; ValueLog.repair_tail truncates them on
      recovery), unsynced index-WAL records (rebuilt by replay: the apply
      of index i happens only after index i's vlog bytes were fsynced, so
      a surviving index record can never point into a lost vlog tail),
      and un-committed GC/merge outputs (orphans pruned by the manifest).

  Reproduce any sweep point from its {seed, crash_index, mode} record:
      PYTHONPATH=src python -c "from repro.core.workload import \
          run_crashpoint; print(run_crashpoint('/tmp/cp', seed=SEED, \
          crash_index=K, mode=MODE))"
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import trace as _trace
from repro.core.cache import BlockCache
from repro.core.faultfs import fs_open, write_json_atomic
from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM
from repro.core.raft import LogStoreBase
from repro.core.storage import (LeveledStore, SortedRun, StorageModule,
                                kway_merge_newest_wins, pack_offset,
                                unpack_offset)
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog


class EngineBase(LogStoreBase):
    name = "base"

    def __init__(self, dirpath: str, metrics: Optional[Metrics] = None, *,
                 sync: bool = False,
                 is_leader: Callable[[], bool] = lambda: True,
                 cache_bytes: int = 2 << 20):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics or Metrics()
        self.sync = sync
        self.is_leader = is_leader
        self.cache = BlockCache(cache_bytes)
        self.user_bytes = 0
        self._meta_path = os.path.join(dirpath, "raft_meta.json")

    # ------------------------------------------------------ LogStore parts
    def persist_meta(self, term: int, voted_for: Optional[int],
                     config: Optional[dict] = None):
        # raft safety state: a lost term/vote re-grants a vote after
        # restart, and a lost membership config re-widens a quorum the
        # node already narrowed — so this must survive kill -9 => full
        # atomic pattern.  `config` is {"index", "voters", "learners"}.
        meta = {"term": term, "voted_for": voted_for}
        if config is not None:
            meta["config"] = config
        write_json_atomic(self._meta_path, meta)
        self.metrics.on_write("raft_meta", 32)

    def load_meta(self) -> Tuple[int, Optional[int], Optional[dict]]:
        if not os.path.exists(self._meta_path):
            return 0, None, None
        with open(self._meta_path) as f:
            m = json.load(f)
        return m["term"], m["voted_for"], m.get("config")

    # -------------------------------------------------------- state machine
    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Apply one committed drain as a group; engines override to
        coalesce their index/WAL writes.  Default: per-entry apply."""
        for e, off in pairs:
            self.apply(e, off)

    # --------------------------------------------------------- maintenance
    def post_op(self):
        """Called by the cluster between requests (GC trigger point)."""

    def snapshot(self):
        return None

    def install_snapshot(self, last_index: int, last_term: int, payload,
                         keep_tail: bool = True):
        raise NotImplementedError(f"{self.name} has no snapshot support")

    def recover(self):
        """Rebuild state after a crash. Returns (entries, offsets,
        snap_index, snap_term) for the Raft log reconstruction."""
        raise NotImplementedError

    def close(self):
        pass


# =====================================================================
class OriginalEngine(EngineBase):
    """Raft + LSM-tree with WAL: the traditional >=3x-write design."""
    name = "original"
    wal = True

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.raft_vlog = ValueLog(os.path.join(dirpath, "raft.log"),
                                  self.metrics, category="raft_log",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self._offsets: List[int] = []  # raft index (1-based) -> offset
        self.db = MiniLSM(os.path.join(dirpath, "db"), self.metrics,
                          wal=self.wal, sync=self.sync, group_commit=True,
                          cache=self.cache)

    # LogStore
    def append(self, entry: LogEntry) -> int:
        off = self.raft_vlog.append(entry)
        if entry.index == len(self._offsets) + 1:
            self._offsets.append(off)
        else:  # replacement after truncation
            self._offsets[entry.index - 1:] = [off]
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries or entries[0].index != len(self._offsets) + 1:
            return [self.append(e) for e in entries]   # rare truncation path
        offs = self.raft_vlog.append_batch(entries)    # ONE buffered write
        self._offsets.extend(offs)
        return offs

    def commit_window(self):
        self.raft_vlog.sync_now()
        self.db.sync_wal()

    def truncate_from(self, index: int):
        self.raft_vlog.truncate_to(self._offsets[index - 1])
        self._offsets = self._offsets[:index - 1]

    # state machine
    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        self.db.put(entry.key, entry.value)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        if not pairs:
            return
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        self.db.put_batch([(e.key, e.value) for e, _ in pairs])

    def get(self, key: bytes) -> Optional[bytes]:
        return self.db.get(key)

    def scan(self, lo: bytes, hi: bytes):
        return self.db.scan(lo, hi)

    def recover(self):
        self.db.recover()
        self.raft_vlog.repair_tail()   # torn tail = unacked, drop it
        entries, offsets = [], []
        for off, e in self.raft_vlog.scan():
            entries.append(e)
            offsets.append(off)
        self._offsets = offsets
        return entries, offsets, 0, 0

    def close(self):
        self.raft_vlog.close()
        self.db.close()


class PASVEngine(OriginalEngine):
    """FAST'22 PASV: drop the storage-engine WAL (passive persistence); the
    raft log doubles as the redo log on recovery."""
    name = "pasv"
    wal = False

    def recover(self):
        entries, offsets, si, st = super().recover()
        # passive data persistence: replay committed-but-unflushed entries
        for e in entries:
            if e.kind == KIND_PUT and self.db.get(e.key) is None:
                self.db.put(e.key, e.value)
        return entries, offsets, si, st


class DwisckeyEngine(EngineBase):
    """WiscKey below an unmodified Raft: value hits disk twice (raft log +
    engine value log); scans read scattered offsets (no GC reorg)."""
    name = "dwisckey"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.raft_vlog = ValueLog(os.path.join(dirpath, "raft.log"),
                                  self.metrics, category="raft_log",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self._offsets: List[int] = []
        self.wisc_vlog = ValueLog(os.path.join(dirpath, "wisc_vlog.log"),
                                  self.metrics, category="wisckey_vlog",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self.db = MiniLSM(os.path.join(dirpath, "db"), self.metrics,
                          wal=True, sync=self.sync, group_commit=True,
                          cache=self.cache)

    def append(self, entry: LogEntry) -> int:
        off = self.raft_vlog.append(entry)
        if entry.index == len(self._offsets) + 1:
            self._offsets.append(off)
        else:
            self._offsets[entry.index - 1:] = [off]
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries or entries[0].index != len(self._offsets) + 1:
            return [self.append(e) for e in entries]
        offs = self.raft_vlog.append_batch(entries)
        self._offsets.extend(offs)
        return offs

    def commit_window(self):
        self.raft_vlog.sync_now()
        self.wisc_vlog.sync_now()
        self.db.sync_wal()

    def truncate_from(self, index: int):
        self.raft_vlog.truncate_to(self._offsets[index - 1])
        self._offsets = self._offsets[:index - 1]

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        voff = self.wisc_vlog.append(entry)       # second value write
        self.db.put(entry.key, pack_offset(voff))

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        if not pairs:
            return
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        voffs = self.wisc_vlog.append_batch([e for e, _ in pairs])
        self.db.put_batch([(e.key, pack_offset(vo))
                           for (e, _), vo in zip(pairs, voffs)])

    def get(self, key: bytes) -> Optional[bytes]:
        v = self.db.get(key)
        if v is None:
            return None
        return self.wisc_vlog.read_value_at(unpack_offset(v))

    def scan(self, lo: bytes, hi: bytes):
        out = []
        for k, v in self.db.scan(lo, hi):
            out.append((k, self.wisc_vlog.read_value_at(unpack_offset(v))))
        return out

    def recover(self):
        self.db.recover()
        self.raft_vlog.repair_tail()
        self.wisc_vlog.repair_tail()
        entries, offsets = [], []
        for off, e in self.raft_vlog.scan():
            entries.append(e)
            offsets.append(off)
        self._offsets = offsets
        return entries, offsets, 0, 0

    def close(self):
        self.raft_vlog.close()
        self.wisc_vlog.close()
        self.db.close()


class _ShippedLSM(MiniLSM):
    """Follower LSM under LSM-Raft: compacted SSTs arrive over the network,
    so compaction costs one write ('sst_ship') and zero local reads."""

    def compact(self):
        self.compaction_count += 1
        from repro.core.minilsm import SortedDict
        merged = SortedDict()
        for sst in self.l1 + self.l0:
            for k, v in sst.items():
                merged[k] = v   # bytes arrive from the leader: no local read
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        from repro.core.minilsm import SSTable
        new_l1 = SSTable.write(path, list(merged.items()), self.metrics,
                               "sst_ship", self.cache)
        self.metrics.on_ship("sst", new_l1.size)   # arrived over the wire
        for sst in self.l0 + self.l1:
            sst.delete()
        self.l0, self.l1 = [], [new_l1]


class LSMRaftEngine(OriginalEngine):
    """SIGMOD'25 LSM-Raft: follower-side redundancy removed (no WAL, shipped
    compaction); the LEADER still writes everything — the paper's point is
    that the leader dominates the critical path."""
    name = "lsmraft"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        if not self.is_leader():
            self.db.close()
            self.db = _ShippedLSM(os.path.join(dirpath, "db"), self.metrics,
                                  wal=False, sync=self.sync,
                                  group_commit=True, cache=self.cache)


# =====================================================================
class NezhaNoGCEngine(EngineBase):
    """KVS-Raft without GC: the raft log IS the ValueLog (single value
    write); the LSM index holds only 8-byte offsets."""
    name = "nezha_nogc"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.active = StorageModule(dirpath, self.metrics, "m0000",
                                    sync=self.sync, group_commit=True,
                                    cache=self.cache)
        self._off_of_index: Dict[int, int] = {}   # raft index -> vlog offset

    # LogStore: append == the one and only value persistence
    def append(self, entry: LogEntry) -> int:
        off = self.active.vlog.append(entry)
        self._off_of_index[entry.index] = off
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        offs = self.active.vlog.append_batch(entries)
        for e, off in zip(entries, offs):
            self._off_of_index[e.index] = off
        return offs

    def commit_window(self):
        self.active.sync_now()

    def truncate_from(self, index: int):
        off = self._off_of_index[index]           # direct lookup, O(1)
        self.active.vlog.truncate_to(off)
        self._off_of_index = {i: o for i, o in self._off_of_index.items()
                              if i < index}

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        self.active.apply(entry, offset)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        if not pairs:
            return
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        self.active.apply_batch(pairs)

    def get(self, key: bytes) -> Optional[bytes]:
        return self.active.get(key)

    def scan(self, lo: bytes, hi: bytes):
        return self.active.scan(lo, hi)

    def recover(self):
        self.active.db.recover()
        self.active.vlog.repair_tail()
        entries, offsets = [], []
        # header-only: offsets suffice to replay the state machine
        for off, e in self.active.vlog.scan_headers():
            entries.append(e)
            offsets.append(off)
            self._off_of_index[e.index] = off
        return entries, offsets, 0, 0

    def load_full_entry(self, index: int, offset: int) -> LogEntry:
        return self.active.vlog.read_at(offset)

    def close(self):
        self.active.close()


class NezhaEngine(EngineBase):
    """Full Nezha: KVS-Raft + Raft-aware leveled GC + three-phase request
    routing (paper Algorithms 1-3, Table I, §III-D).

    GC of the active segment seals a new L0 run in the LeveledStore
    (bounded work per cycle — O(active segment), independent of total
    data); when a level accumulates `level_fanout` runs they merge,
    incrementally, into one run on the next level.  Reads stream through
    a k-way newest-wins heap over (New, Active, runs newest-first); point
    gets are bloom-gated per run."""
    name = "nezha"

    def __init__(self, dirpath, metrics=None, *, gc_threshold: int = 32 << 20,
                 gc_batch: int = 64, level_fanout: int = 4,
                 on_snapshot=None, run_shipping: bool = True, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.gc_threshold = gc_threshold
        self.gc_batch = gc_batch
        self.level_fanout = level_fanout
        self.on_snapshot = on_snapshot  # callback(last_index, last_term)
        # run shipping (replication tier 2, ON by default): GC is
        # leader-gated; sealed runs flow to ship_hook (the RunShipper) and
        # followers install them via adopt_run instead of compacting
        # locally.  run_shipping=False is the explicit opt-out for local-GC
        # baselines.  Standalone engines (no cluster wiring) are unaffected:
        # is_leader defaults to True, so GC still runs and ship_hook stays
        # unset.
        self.run_shipping = run_shipping
        self.ship_hook = None   # callback(record dict, run bytes)
        self.raft_role = None   # callable() -> is this node the leader NOW
        self.adopt_count = 0
        self.gen = 0
        self.active = StorageModule(dirpath, self.metrics,
                                    f"m{self.gen:04d}", sync=self.sync,
                                    group_commit=True, cache=self.cache)
        self.new: Optional[StorageModule] = None
        self.leveled = LeveledStore(dirpath, self.metrics, cache=self.cache,
                                    fanout=level_fanout)
        self.gc_started = False
        self.gc_completed = True  # no GC yet
        self.gc_count = 0
        self._state_path = os.path.join(dirpath, "gc_state.json")
        # raft index -> (segment tag, vlog offset): one map serves both
        # module routing and O(1) truncation
        self._seg_of_index: Dict[int, Tuple[str, int]] = {}
        self._gc_iter: Optional[Iterator] = None
        self._gc_last: Tuple[int, int] = (0, 0)     # last APPLIED (idx, term)
        self._building: Optional[SortedRun] = None
        self._cycle_bytes = 0                       # L0 bytes this cycle
        self._merge: Optional[dict] = None          # in-flight level merge
        self._last_by_tag: Dict[str, Tuple[int, int]] = {}
        self._boundary: Tuple[int, int] = (0, 0)    # GC snapshot point

    def _write_gc_state(self, st: dict):
        """gc_state.json is the rotation/flush commit point: it must never
        be observable half-written or lost after a rename, so it commits
        through the audited atomic pattern.  Byte accounting stays at the
        call sites (not every site charged gc_meta historically)."""
        write_json_atomic(self._state_path, st)

    # --------------------------------------------------------- log store
    def _write_module(self) -> StorageModule:
        return self.new if self.new is not None else self.active

    def _purge_module(self, tag: str):
        """Remove any files a crashed rotation left under `tag`."""
        StorageModule(self.dir, self.metrics, tag, sync=self.sync,
                      group_commit=True, cache=self.cache).destroy()

    def _fresh_module(self, tag: str) -> StorageModule:
        """Storage module at `tag`, guaranteed empty."""
        self._purge_module(tag)
        return StorageModule(self.dir, self.metrics, tag, sync=self.sync,
                             group_commit=True, cache=self.cache)

    def append(self, entry: LogEntry) -> int:
        mod = self._write_module()
        off = mod.vlog.append(entry)
        self._seg_of_index[entry.index] = (mod.tag, off)
        self._last_by_tag[mod.tag] = (entry.index, entry.term)
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries:
            return []
        mod = self._write_module()
        offs = mod.vlog.append_batch(entries)      # ONE buffered write
        for e, off in zip(entries, offs):
            self._seg_of_index[e.index] = (mod.tag, off)
        last = entries[-1]
        self._last_by_tag[mod.tag] = (last.index, last.term)
        return offs

    def commit_window(self):
        self.active.sync_now()
        if self.new is not None:
            self.new.sync_now()

    def truncate_from(self, index: int):
        mod = self._write_module()
        tag, off = self._seg_of_index[index]       # direct lookup, O(1)
        assert tag == mod.tag, \
            "conflict truncation across GC segments is not supported"
        mod.vlog.truncate_to(off)
        self._seg_of_index = {i: v for i, v in self._seg_of_index.items()
                              if i < index}
        # the segment's last-persisted marker moved back with the tail
        rest = [(i, v[1]) for i, v in self._seg_of_index.items()
                if v[0] == mod.tag]
        if rest:
            last_i, last_off = max(rest)
            self._last_by_tag[mod.tag] = (last_i,
                                          mod.vlog.read_at(last_off).term)
        else:
            self._last_by_tag.pop(mod.tag, None)

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        mod = self._module_of(entry.index)
        mod.apply(entry, offset)
        self._gc_last = (entry.index, entry.term)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Group apply; a batch may straddle the Active->New rotation, so
        coalesce per consecutive-module run (order within the drain is
        preserved)."""
        if not pairs:
            return
        run: List[Tuple[LogEntry, int]] = []
        run_mod = None
        for e, off in pairs:
            self.user_bytes += len(e.key) + len(e.value)
            mod = self._module_of(e.index)
            if mod is not run_mod and run:
                run_mod.apply_batch(run)
                run = []
            run_mod = mod
            run.append((e, off))
        if run:
            run_mod.apply_batch(run)
        last = pairs[-1][0]
        self._gc_last = (last.index, last.term)

    def _module_of(self, index: int) -> StorageModule:
        tag = self._seg_of_index.get(index, (None, 0))[0]
        return self.new if (self.new is not None and tag == self.new.tag) \
            else self.active

    def load_full_entry(self, index: int, offset: int) -> LogEntry:
        return self._module_of(index).vlog.read_at(offset)

    # ------------------------------------------------------- three-phase
    def get(self, key: bytes) -> Optional[bytes]:
        if self.new is not None:
            v = self.new.get(key)
            if v is not None:
                return v
        v = self.active.get(key)
        if v is not None:
            return v
        return self.leveled.get(key)     # newest-first runs, bloom-gated

    def scan_iter(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        """Streaming k-way heap merge over (New, Active, L0..Lk), newest
        first with newest-wins dedup — nothing is materialized."""
        sources = []
        if self.new is not None:
            sources.append(self.new.scan_iter(lo, hi))
        sources.append(self.active.scan_iter(lo, hi))
        sources.extend(self.leveled.scan_sources(lo, hi))
        return kway_merge_newest_wins(sources)

    def scan(self, lo: bytes, hi: bytes):
        return list(self.scan_iter(lo, hi))

    # ---------------------------------------------------------------- GC
    def post_op(self):
        """Maintenance trigger point between requests: one bounded slice of
        the in-flight job, else start the next job.  At most one job (an
        active-segment flush or a level merge) runs at a time.  With run
        shipping on, only the leader starts jobs — followers receive the
        sealed output instead (a job already in flight when leadership is
        lost still drains; the new leader's fence/resync covers us)."""
        if self.gc_started and not self.gc_completed:
            self._gc_unit("gc.flush", self.gc_step, self.gc_batch)
        elif self._merge is not None:
            self._gc_unit("gc.merge", self.merge_step, self.gc_batch)
        elif not self._gc_allowed():
            return
        elif self.active.vlog.size >= self.gc_threshold:
            self._gc_unit("gc.flush.start", self.start_gc)
        else:
            level = self.leveled.needs_merge()
            if level is not None:
                self._gc_unit("gc.merge.start", self.start_level_merge,
                              level)

    def _gc_unit(self, name: str, fn, *args):
        """Run one bounded GC slice, wrapped in a trace span when a tracer
        is installed — GC interference shows up INSIDE the client op span
        whose post_op hook paid for it."""
        t = _trace._ACTIVE
        if t is None:
            fn(*args)
            return
        sid = t.begin(name, kind="gc", node=self.metrics.node)
        try:
            fn(*args)
        finally:
            t.end(sid)

    def _gc_allowed(self) -> bool:
        if not self.run_shipping:
            return True
        role = self.raft_role if self.raft_role is not None else self.is_leader
        return bool(role())

    def start_gc(self):
        assert self.gc_completed, "GC already running"
        if self._last_by_tag.get(self.active.tag) is None:
            return   # empty active segment: nothing to compact
        while self._merge is not None:   # direct callers may race a merge
            self.merge_step(1024)
        self.gc_started, self.gc_completed = True, False
        self.gc_count += 1
        self._cycle_bytes = 0
        # snapshot point = last entry PERSISTED into the active segment; the
        # compaction may only consume (and later drop) the active segment
        # once everything up to this point has committed+applied — Raft's
        # log-completeness is preserved (paper §III-E).
        self._boundary = self._last_by_tag.get(self.active.tag, (0, 0))
        self.gen += 1
        self.new = self._fresh_module(f"m{self.gen:04d}")
        self._building = SortedRun(self.dir, self.metrics,
                                   self.leveled.alloc_rid(), level=0,
                                   cache=self.cache)
        fs_open(self._building.path, "wb").close()
        self._building._started = True
        self._write_gc_state({"started": True, "complete": False,
                              "gen": self.gen, "rid": self._building.rid,
                              "last_index": self._boundary[0],
                              "last_term": self._boundary[1]})
        self.metrics.on_write("gc_meta", 64)
        self._gc_snapshot_point = self._boundary
        self._gc_iter = None  # built once the boundary has been applied

    def _live_active_items(self):
        """Key-ascending live data of the Active segment (via its index,
        already deduped+sorted).  Unlike the old monolithic design this
        never re-reads previously compacted data: one GC cycle's work is
        O(active segment), not O(total store)."""
        for key, off in self.active.sorted_items():
            yield key, self.active.vlog.read_at(off)   # scattered GC read

    def gc_step(self, n: int):
        """Advance compaction by n entries; requests interleave freely."""
        if self._gc_iter is None:
            # barrier: wait until the whole active segment has applied
            if self._gc_last[0] < self._gc_snapshot_point[0]:
                return
            self._gc_iter = self._live_active_items()
        buf = []
        done = False
        for _ in range(n):
            item = next(self._gc_iter, None)
            if item is None:
                done = True
                break
            buf.append(item)
        if buf:
            self._cycle_bytes += self._building.append_items(buf,
                                                             "gc_sorted")
        if done:
            self.finish_gc()

    def finish_gc(self):
        li, lt = self._gc_snapshot_point
        boundary_before = self.leveled.boundary
        runs_before = len(self.leveled.runs)
        sealed = self._building
        sealed.seal(li, lt)
        self.leveled.add_l0(sealed, (li, lt))
        self._building = None
        self._gc_iter = None
        # cleanup phase: drop the consumed Active segment
        old_tag = self.active.tag
        self.active.destroy()
        # role rotation: New becomes Active
        self.active = self.new
        self.new = None
        self.gc_completed = True
        # prune raft-index maps below the GC boundary: every index <= li
        # lived in the destroyed segment (the maps stay O(live tail))
        self._seg_of_index = {i: v for i, v in self._seg_of_index.items()
                              if i > li}
        self._last_by_tag.pop(old_tag, None)
        self.metrics.on_gc_cycle("flush", self._cycle_bytes, 0,
                                 self.gc_count)
        self._write_gc_state({"started": True, "complete": True,
                              "gen": self.gen, "last_index": li,
                              "last_term": lt})
        self.metrics.on_write("gc_meta", 64)
        # _gc_allowed: a deposed leader draining its in-flight job must
        # not pay the export read — the shipper would drop it anyway
        if self.run_shipping and self.ship_hook is not None and \
                self._gc_allowed():
            self.ship_hook({"kind": "flush", "level": 0,
                            "last_index": li, "last_term": lt,
                            "boundary_before": boundary_before,
                            "runs_before": runs_before,
                            "boundary": (li, lt), "retire": []},
                           self.leveled.export_run(sealed))
        if self.on_snapshot is not None:
            self.on_snapshot(li, lt)

    # ------------------------------------------------------- level merges
    def start_level_merge(self, level: int):
        """Begin merging every run of `level` into one run on level+1.
        Progress is incremental (merge_step) and crash-safe: the output is
        invisible until commit_merge swaps the manifest, so a crash simply
        discards the partial output and retries later."""
        inputs = self.leveled.level_runs(level)    # newest-first
        out = SortedRun(self.dir, self.metrics, self.leveled.alloc_rid(),
                        level=level + 1, cache=self.cache)
        fs_open(out.path, "wb").close()
        self._merge = {
            "out": out, "inputs": inputs, "level": level, "bytes": 0,
            "iter": kway_merge_newest_wins([r.items() for r in inputs]),
        }

    def merge_step(self, n: int):
        job = self._merge
        out = job["out"]
        buf = []
        done = False
        for _ in range(n):
            item = next(job["iter"], None)
            if item is None:
                done = True
                break
            buf.append(item)
        if buf:
            job["bytes"] += out.append_items(buf, "gc_level_merge")
        if done:
            self.finish_level_merge()

    def finish_level_merge(self):
        job = self._merge
        out, inputs = job["out"], job["inputs"]
        # the merged run is complete up to its newest input's boundary
        newest = max(inputs, key=lambda r: r.last_index)
        out.seal(newest.last_index, newest.last_term)
        retire = [(r.level, r.last_index) for r in inputs]
        runs_before = len(self.leveled.runs)
        self.leveled.commit_merge(out, inputs)
        self.metrics.on_gc_cycle("merge", job["bytes"], job["level"] + 1,
                                 self.gc_count)
        self._merge = None
        if self.run_shipping and self.ship_hook is not None and \
                self._gc_allowed():
            self.ship_hook({"kind": "merge", "level": out.level,
                            "last_index": out.last_index,
                            "last_term": out.last_term,
                            "boundary_before": self.leveled.boundary,
                            "runs_before": runs_before,
                            "boundary": self.leveled.boundary,
                            "retire": retire},
                           self.leveled.export_run(out))

    # ------------------------------------------------------- run adoption
    def adopt_run(self, rec: dict, data: bytes):
        """Follower side of run shipping: install a leader-sealed run and
        retire exactly the inputs the leader consumed — in place of local
        GC.  The caller (RunAdopter) must have applied the log through
        rec['last_index'] first.  Returns (ok, new_offsets): ok=False means
        a fence tripped (divergent manifest, concurrent local GC of a
        deposed leader, stale record) and the caller should fall back to
        snapshot catch-up; new_offsets maps the surviving raft-tail indices
        to their rewritten vlog offsets after a flush adoption."""
        pos = tuple(rec["pos"])
        if pos <= tuple(self.leveled.ship_pos):
            return False, None            # stale/duplicate record
        if (self.gc_started and not self.gc_completed) or \
                self._merge is not None or self.new is not None:
            return False, None            # mid-local-GC (deposed leader)
        if tuple(rec["boundary_before"]) != tuple(self.leveled.boundary):
            return False, None            # manifests diverged
        if rec.get("runs_before", len(self.leveled.runs)) != \
                len(self.leveled.runs):
            # structural gap: records were missed (e.g. merges across a
            # leadership change leave the boundary unchanged, so the
            # boundary fence alone would not see it) — resync instead of
            # silently forking the run hierarchy
            return False, None
        li, lt = rec["last_index"], rec["last_term"]
        if rec["kind"] == "merge":
            try:
                self.leveled.adopt_run(rec["level"], li, lt, data,
                                       [tuple(x) for x in rec["retire"]],
                                       self.leveled.boundary, pos)
            except ValueError:
                return False, None        # an input run is missing
            self.adopt_count += 1
            self.metrics.on_gc_cycle("adopt", len(data), rec["level"],
                                     self.adopt_count)
            return True, None
        # flush: install the L0 run, then retire the covered Active prefix
        # (the leader dropped its whole active segment; we keep only the
        # raft tail past the boundary, rewritten into a fresh segment)
        self.leveled.adopt_run(0, li, lt, data, [], (li, lt), pos)
        new_offsets = self._retire_active_prefix(li, lt)
        self._gc_last = max(self._gc_last, (li, lt))
        self.adopt_count += 1
        self.metrics.on_gc_cycle("adopt", len(data), 0, self.adopt_count)
        return True, new_offsets

    def _retire_active_prefix(self, li: int, lt: int) -> Dict[int, int]:
        """Adopt-path rotation: replace Active with a fresh segment holding
        only the raft tail (index > li), re-applying the already-applied
        puts at their new offsets.  O(tail), not O(segment) — the adopted
        run replaces everything at or below the boundary.

        Crash ordering: the new segment is fully built + synced, THEN
        gc_state.json moves the generation (the commit point), THEN the
        old segment is deleted.  Before the state write the old segment is
        authoritative (the adopted run merely duplicates its prefix, which
        reads tolerate); after it the old files are orphans."""
        old = self.active
        tail = sorted((i, off) for i, (tag, off) in self._seg_of_index.items()
                      if i > li and tag == old.tag)
        entries = [old.vlog.read_at(off) for _, off in tail]
        self._last_by_tag.pop(old.tag, None)
        mod, new_offsets = self._build_tail_segment(entries)
        self._write_gc_state({"started": False, "complete": True,
                              "gen": self.gen, "last_index": li,
                              "last_term": lt})   # rotation commit point
        self.metrics.on_write("gc_meta", 64)
        old.destroy()
        self.active = mod
        return new_offsets

    def _build_tail_segment(self, entries: List[LogEntry]):
        """Fresh segment holding exactly `entries` (a raft tail, one per
        index, ascending), with the already-applied puts re-applied at
        their new offsets; _seg_of_index/_last_by_tag are re-pointed at
        it.  Shared by the adopt-path rotation and snapshot install so
        the rebuild rules can't drift.  Returns (module, {index: off})."""
        self.gen += 1
        mod = self._fresh_module(f"m{self.gen:04d}")
        offs = mod.vlog.append_batch(entries) if entries else []
        applied = self._gc_last[0]
        pairs = [(e, off) for e, off in zip(entries, offs)
                 if e.kind == KIND_PUT and e.index <= applied]
        if pairs:
            mod.apply_batch(pairs)
        mod.sync_now()
        self._seg_of_index = {e.index: (mod.tag, off)
                              for e, off in zip(entries, offs)}
        if entries:
            self._last_by_tag[mod.tag] = (entries[-1].index,
                                          entries[-1].term)
        return mod, {e.index: off for e, off in zip(entries, offs)}

    def run_gc_to_completion(self):
        """Drain the in-flight flush plus any cascading level merges."""
        while True:
            if self.gc_started and not self.gc_completed:
                self.gc_step(1024)
            elif self._merge is not None:
                self.merge_step(1024)
            else:
                level = self.leveled.needs_merge()
                if level is None:
                    return
                self.start_level_merge(level)

    # ----------------------------------------------------------- recovery
    def recover(self):
        state = {}
        if os.path.exists(self._state_path):
            with open(self._state_path) as f:
                state = json.load(f)
        gen = state.get("gen", 0)
        self.gen = gen
        mid_gc = bool(state.get("started")) and not state.get("complete")
        # the manifest is authoritative for the committed run set; a run
        # file it does not list is a crashed level-merge output -> pruned
        self.leveled = LeveledStore(self.dir, self.metrics, cache=self.cache,
                                    fanout=self.level_fanout)
        self.leveled.load()
        keep: Tuple[str, ...] = ()
        b: Optional[SortedRun] = None
        if mid_gc:
            # a state file without 'rid' (legacy writer) can't name its
            # partial run: allocate a fresh one and let the flush restart
            # from the barrier — the old active segment still holds it all
            rid = state.get("rid")
            if rid is None:
                rid = self.leveled.alloc_rid()
            b = SortedRun(self.dir, self.metrics, rid, level=0,
                          cache=self.cache)
            keep = (b.path, b.meta_path)
        self.leveled.prune_orphans(keep=keep)
        self._merge = None   # an unfinished merge is simply retried later
        if mid_gc and any(r.rid == state.get("rid")
                          for r in self.leveled.runs):
            # crash landed between add_l0's manifest commit and the final
            # gc_state write: the flush IS committed; only the cleanup /
            # rotation remained.  Redo it idempotently instead of
            # re-adding the run.
            old = StorageModule(self.dir, self.metrics, f"m{gen - 1:04d}",
                                sync=self.sync, group_commit=True,
                                cache=self.cache)
            old.destroy()
            self.active = StorageModule(self.dir, self.metrics,
                                        f"m{gen:04d}", sync=self.sync,
                                        group_commit=True, cache=self.cache)
            self.active.db.recover()
            self.new = None
            self.gc_started, self.gc_completed = True, True
            self._gc_last = self.leveled.boundary
            li, lt = self.leveled.boundary
            self._write_gc_state({"started": True, "complete": True,
                                  "gen": gen, "last_index": li,
                                  "last_term": lt})
        elif mid_gc:
            # crashed mid-flush: resume from the interrupt point (§III-E)
            self.active = StorageModule(self.dir, self.metrics,
                                        f"m{gen - 1:04d}", sync=self.sync,
                                        group_commit=True, cache=self.cache)
            self.active.db.recover()
            self.new = StorageModule(self.dir, self.metrics,
                                     f"m{gen:04d}", sync=self.sync,
                                     group_commit=True, cache=self.cache)
            self.new.db.recover()
            self._building = b
            resume_key = self._building.load_partial()
            self.gc_started, self.gc_completed = True, False
            self._gc_snapshot_point = (state["last_index"],
                                       state["last_term"])
            self._boundary = self._gc_snapshot_point
            self._gc_last = (0, 0)  # re-applied by raft replay after restart
            if resume_key is not None:
                # compaction had begun => the barrier had passed pre-crash
                # and the active db was WAL-recovered: resume immediately
                # after the interrupt point (paper §III-E).
                self._gc_last = self._gc_snapshot_point
                full = self._live_active_items()
                self._gc_iter = (x for x in full if x[0] > resume_key)
            else:
                self._gc_iter = None  # barrier re-evaluated in gc_step
        else:
            # every complete-state generation owns exactly one live
            # segment: m{gen-1} (crash between a rotation commit and the
            # old segment's deletion) and m{gen+1} (crash between a
            # rotation build and its commit) are orphans — purge both
            for g in (gen - 1, gen + 1):
                leftover = os.path.join(self.dir, f"valuelog_m{g:04d}.log")
                if g >= 0 and os.path.exists(leftover):
                    self._purge_module(f"m{g:04d}")
            self.active = StorageModule(self.dir, self.metrics,
                                        f"m{gen:04d}", sync=self.sync,
                                        group_commit=True, cache=self.cache)
            self.active.db.recover()
            self.new = None
            self.gc_started = bool(state.get("started"))
            self.gc_completed = True
            if self.leveled.runs:
                self._gc_last = self.leveled.boundary
        # rebuild raft tail from the live vlogs — HEADER-ONLY scan: the
        # KVS-Raft state machine replays (key, offset), never values
        # (the paper's Fig. 11 recovery win).  Values hydrate lazily via
        # load_full_entry when the node must replicate old entries.
        entries, offsets = [], []
        mods = [self.active] + ([self.new] if self.new else [])
        for mod in mods:
            mod.vlog.repair_tail()   # torn tail = unacked by contract
            for off, e in mod.vlog.scan_headers():
                entries.append(e)
                offsets.append(off)
                self._seg_of_index[e.index] = (mod.tag, off)
                self._last_by_tag[mod.tag] = (e.index, e.term)
        si, st = self.leveled.boundary if self.leveled.runs else (0, 0)
        scanned = len(entries)
        entries = [e for e in entries if e.index > si]
        offsets = offsets[-len(entries):] if entries else []
        self._seg_of_index = {i: v for i, v in self._seg_of_index.items()
                              if i > si}
        if self.gc_completed and self.new is None and si and \
                scanned != len(entries):
            # the active segment still holds records at/below the manifest
            # boundary: a crash landed between an install/adoption commit
            # and its rotation.  Rebuild the segment tail-only — stale
            # applied records must not shadow newer run data (a catch-up
            # snapshot's contents can be AHEAD of what this node applied).
            old = self.active
            full = [old.vlog.read_at(self._seg_of_index[e.index][1])
                    for e in entries]
            self._last_by_tag.clear()
            self.active, new_offs = self._build_tail_segment(full)
            self._write_gc_state({"started": False, "complete": True,
                                  "gen": self.gen, "last_index": si,
                                  "last_term": st})
            self.metrics.on_write("gc_meta", 64)
            old.destroy()
            offsets = [new_offs[e.index] for e in entries]
        return entries, offsets, si, st

    # ----------------------------------------------------------- snapshot
    def snapshot(self):
        if not self.leveled.runs:
            return None
        li, lt = self.leveled.boundary
        return li, lt, self.leveled.snapshot_payload()

    def install_snapshot(self, last_index: int, last_term: int, payload,
                         keep_tail: bool = True):
        """A shipped snapshot replaces the run hierarchy and everything at
        or below its boundary; the raft tail PAST the boundary is retained
        (rewritten into the fresh segment, like a run adoption's rotation)
        because a resync snapshot can lag entries this follower already
        applied — destroying those would silently regress the state
        machine.  keep_tail=False (raft's term check at the boundary
        failed: the local suffix is a divergent, necessarily-unapplied
        lineage the node is discarding) drops the tail instead — keeping
        it would leave stale duplicate indices in the fresh vlog for the
        leader's re-sent entries to collide with at recovery.  Returns
        {index: new vlog offset} for the retained tail so the raft node
        can re-point its log.  Any local GC/merge is aborted: its
        inputs/outputs are superseded."""
        if self._building is not None:
            self._building.destroy()
            self._building = None
        self._gc_iter = None
        if self._merge is not None:
            self._merge["out"].destroy()
            self._merge = None
        self.gc_started, self.gc_completed = False, True
        mods = {self.active.tag: self.active}
        if self.new is not None:
            mods[self.new.tag] = self.new
        entries = []
        if keep_tail:
            tail = sorted((i, v) for i, v in self._seg_of_index.items()
                          if i > last_index)
            entries = [mods[tag].vlog.read_at(off) for _, (tag, off) in tail
                       if tag in mods]
        if self.new is not None:
            self.new.destroy()
            self.new = None
        old = self.active
        self._last_by_tag.clear()
        self.active, new_offsets = self._build_tail_segment(entries)
        self.leveled.install_payload(payload, last_index, last_term)
        self._gc_last = max(self._gc_last, (last_index, last_term))
        self._write_gc_state({"started": False, "complete": True,
                              "gen": self.gen, "last_index": last_index,
                              "last_term": last_term})
        # deletion comes last: a crash anywhere above leaves the old
        # segment for recovery's orphan purge / below-boundary repair
        old.destroy()
        return new_offsets

    def close(self):
        self.active.close()
        if self.new is not None:
            self.new.close()
        if self._building is not None:
            self._building.close()
        if self._merge is not None:
            self._merge["out"].close()
        self.leveled.close()


ENGINES = {
    "original": OriginalEngine,
    "pasv": PASVEngine,
    "dwisckey": DwisckeyEngine,
    "lsmraft": LSMRaftEngine,
    "tikv": OriginalEngine,       # paper: TiKV follows the Original design
    "nezha_nogc": NezhaNoGCEngine,
    "nezha": NezhaEngine,
}
