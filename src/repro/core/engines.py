"""Storage engines: Nezha and every baseline the paper compares against.

Each engine is simultaneously (a) the Raft log store (persistence of log
entries) and (b) the replicated state machine (apply on commit), matching how
the paper couples/decouples the two layers:

  Original    raft log (full values) + LSM[WAL -> memtable -> SST -> compact]
              => value written >= 3x                      [paper baseline]
  PASV        Original minus the storage-engine WAL (FAST'22)   => >= 2x
  Dwisckey    Original raft log + WiscKey engine (value log below the LSM)
              => 2x value writes, scattered scan reads
  LSM-Raft    Original on the leader; followers skip WAL and receive shipped
              compacted SSTs instead of re-compacting (SIGMOD'25)
  Nezha-NoGC  KVS-Raft: raft log IS the ValueLog, LSM holds key->offset
              => exactly 1x value write; reads pay indirection
  Nezha       Nezha-NoGC + Raft-aware GC (sorted ValueLog + hash index) +
              three-phase request routing

Batching / caching knobs (the group-commit I/O pipeline):

  max_batch (RaftNode/Cluster, default 64)
      Entries shipped per AppendEntries RPC AND the group-commit window:
      client_put_many persists a whole window with one buffered write, and
      commit_window() turns it into ONE fsync (per store) instead of one
      per record.  benchmarks/fig12_batching.py sweeps this knob.
  commit window (LogStoreBase.commit_window)
      Invoked by Raft at batch boundaries: after client_put/client_put_many
      on the leader, after the follower appends an AppendEntries batch
      (before acking), and after each _apply_committed drain.  Engines
      flush+fsync every dirty file exactly once per call.
  cache_bytes (EngineBase, default 2 MiB)
      Byte budget of the per-engine BlockCache shared by SSTable blocks,
      SortedStore point records, and ValueLog offset reads.  Per-SSTable
      bloom filters (cache-independent) skip files on point gets.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.cache import BlockCache
from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM
from repro.core.raft import LogStoreBase
from repro.core.storage import (SortedStore, StorageModule, pack_offset,
                                unpack_offset)
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog


class EngineBase(LogStoreBase):
    name = "base"

    def __init__(self, dirpath: str, metrics: Optional[Metrics] = None, *,
                 sync: bool = False,
                 is_leader: Callable[[], bool] = lambda: True,
                 cache_bytes: int = 2 << 20):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics or Metrics()
        self.sync = sync
        self.is_leader = is_leader
        self.cache = BlockCache(cache_bytes)
        self.user_bytes = 0
        self._meta_path = os.path.join(dirpath, "raft_meta.json")

    # ------------------------------------------------------ LogStore parts
    def persist_meta(self, term: int, voted_for: Optional[int]):
        with open(self._meta_path, "w") as f:
            json.dump({"term": term, "voted_for": voted_for}, f)
        self.metrics.on_write("raft_meta", 32)

    def load_meta(self) -> Tuple[int, Optional[int]]:
        if not os.path.exists(self._meta_path):
            return 0, None
        with open(self._meta_path) as f:
            m = json.load(f)
        return m["term"], m["voted_for"]

    # -------------------------------------------------------- state machine
    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Apply one committed drain as a group; engines override to
        coalesce their index/WAL writes.  Default: per-entry apply."""
        for e, off in pairs:
            self.apply(e, off)

    # --------------------------------------------------------- maintenance
    def post_op(self):
        """Called by the cluster between requests (GC trigger point)."""

    def snapshot(self):
        return None

    def install_snapshot(self, last_index: int, last_term: int, payload):
        raise NotImplementedError(f"{self.name} has no snapshot support")

    def recover(self):
        """Rebuild state after a crash. Returns (entries, offsets,
        snap_index, snap_term) for the Raft log reconstruction."""
        raise NotImplementedError

    def close(self):
        pass


# =====================================================================
class OriginalEngine(EngineBase):
    """Raft + LSM-tree with WAL: the traditional >=3x-write design."""
    name = "original"
    wal = True

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.raft_vlog = ValueLog(os.path.join(dirpath, "raft.log"),
                                  self.metrics, category="raft_log",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self._offsets: List[int] = []  # raft index (1-based) -> offset
        self.db = MiniLSM(os.path.join(dirpath, "db"), self.metrics,
                          wal=self.wal, sync=self.sync, group_commit=True,
                          cache=self.cache)

    # LogStore
    def append(self, entry: LogEntry) -> int:
        off = self.raft_vlog.append(entry)
        if entry.index == len(self._offsets) + 1:
            self._offsets.append(off)
        else:  # replacement after truncation
            self._offsets[entry.index - 1:] = [off]
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries or entries[0].index != len(self._offsets) + 1:
            return [self.append(e) for e in entries]   # rare truncation path
        offs = self.raft_vlog.append_batch(entries)    # ONE buffered write
        self._offsets.extend(offs)
        return offs

    def commit_window(self):
        self.raft_vlog.sync_now()
        self.db.sync_wal()

    def truncate_from(self, index: int):
        self.raft_vlog.truncate_to(self._offsets[index - 1])
        self._offsets = self._offsets[:index - 1]

    # state machine
    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        self.db.put(entry.key, entry.value)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        self.db.put_batch([(e.key, e.value) for e, _ in pairs])

    def get(self, key: bytes) -> Optional[bytes]:
        return self.db.get(key)

    def scan(self, lo: bytes, hi: bytes):
        return self.db.scan(lo, hi)

    def recover(self):
        self.db.recover()
        entries, offsets = [], []
        for off, e in self.raft_vlog.scan():
            entries.append(e)
            offsets.append(off)
        self._offsets = offsets
        return entries, offsets, 0, 0

    def close(self):
        self.raft_vlog.close()
        self.db.close()


class PASVEngine(OriginalEngine):
    """FAST'22 PASV: drop the storage-engine WAL (passive persistence); the
    raft log doubles as the redo log on recovery."""
    name = "pasv"
    wal = False

    def recover(self):
        entries, offsets, si, st = super().recover()
        # passive data persistence: replay committed-but-unflushed entries
        for e in entries:
            if e.kind == KIND_PUT and self.db.get(e.key) is None:
                self.db.put(e.key, e.value)
        return entries, offsets, si, st


class DwisckeyEngine(EngineBase):
    """WiscKey below an unmodified Raft: value hits disk twice (raft log +
    engine value log); scans read scattered offsets (no GC reorg)."""
    name = "dwisckey"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.raft_vlog = ValueLog(os.path.join(dirpath, "raft.log"),
                                  self.metrics, category="raft_log",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self._offsets: List[int] = []
        self.wisc_vlog = ValueLog(os.path.join(dirpath, "wisc_vlog.log"),
                                  self.metrics, category="wisckey_vlog",
                                  sync=self.sync, group_commit=True,
                                  cache=self.cache)
        self.db = MiniLSM(os.path.join(dirpath, "db"), self.metrics,
                          wal=True, sync=self.sync, group_commit=True,
                          cache=self.cache)

    def append(self, entry: LogEntry) -> int:
        off = self.raft_vlog.append(entry)
        if entry.index == len(self._offsets) + 1:
            self._offsets.append(off)
        else:
            self._offsets[entry.index - 1:] = [off]
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries or entries[0].index != len(self._offsets) + 1:
            return [self.append(e) for e in entries]
        offs = self.raft_vlog.append_batch(entries)
        self._offsets.extend(offs)
        return offs

    def commit_window(self):
        self.raft_vlog.sync_now()
        self.wisc_vlog.sync_now()
        self.db.sync_wal()

    def truncate_from(self, index: int):
        self.raft_vlog.truncate_to(self._offsets[index - 1])
        self._offsets = self._offsets[:index - 1]

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        voff = self.wisc_vlog.append(entry)       # second value write
        self.db.put(entry.key, pack_offset(voff))

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        voffs = self.wisc_vlog.append_batch([e for e, _ in pairs])
        self.db.put_batch([(e.key, pack_offset(vo))
                           for (e, _), vo in zip(pairs, voffs)])

    def get(self, key: bytes) -> Optional[bytes]:
        v = self.db.get(key)
        if v is None:
            return None
        return self.wisc_vlog.read_value_at(unpack_offset(v))

    def scan(self, lo: bytes, hi: bytes):
        out = []
        for k, v in self.db.scan(lo, hi):
            out.append((k, self.wisc_vlog.read_value_at(unpack_offset(v))))
        return out

    def recover(self):
        self.db.recover()
        entries, offsets = [], []
        for off, e in self.raft_vlog.scan():
            entries.append(e)
            offsets.append(off)
        self._offsets = offsets
        return entries, offsets, 0, 0

    def close(self):
        self.raft_vlog.close()
        self.wisc_vlog.close()
        self.db.close()


class _ShippedLSM(MiniLSM):
    """Follower LSM under LSM-Raft: compacted SSTs arrive over the network,
    so compaction costs one write ('sst_ship') and zero local reads."""

    def compact(self):
        self.compaction_count += 1
        from repro.core.minilsm import SortedDict
        merged = SortedDict()
        for sst in self.l1 + self.l0:
            for k, v in sst.items():
                merged[k] = v   # bytes arrive from the leader: no local read
        path = os.path.join(self.dir, f"sst_{self._sst_seq:06d}.sst")
        self._sst_seq += 1
        from repro.core.minilsm import SSTable
        new_l1 = SSTable.write(path, list(merged.items()), self.metrics,
                               "sst_ship", self.cache)
        for sst in self.l0 + self.l1:
            sst.delete()
        self.l0, self.l1 = [], [new_l1]


class LSMRaftEngine(OriginalEngine):
    """SIGMOD'25 LSM-Raft: follower-side redundancy removed (no WAL, shipped
    compaction); the LEADER still writes everything — the paper's point is
    that the leader dominates the critical path."""
    name = "lsmraft"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        if not self.is_leader():
            self.db.close()
            self.db = _ShippedLSM(os.path.join(dirpath, "db"), self.metrics,
                                  wal=False, sync=self.sync,
                                  group_commit=True, cache=self.cache)


# =====================================================================
class NezhaNoGCEngine(EngineBase):
    """KVS-Raft without GC: the raft log IS the ValueLog (single value
    write); the LSM index holds only 8-byte offsets."""
    name = "nezha_nogc"

    def __init__(self, dirpath, metrics=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.active = StorageModule(dirpath, self.metrics, "m0000",
                                    sync=self.sync, group_commit=True,
                                    cache=self.cache)

    # LogStore: append == the one and only value persistence
    def append(self, entry: LogEntry) -> int:
        return self.active.vlog.append(entry)

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        return self.active.vlog.append_batch(entries)

    def commit_window(self):
        self.active.sync_now()

    def truncate_from(self, index: int):
        # offsets tracked by the raft node; scan to find (rare path)
        for off, e in self.active.vlog.scan():
            if e.index == index:
                self.active.vlog.truncate_to(off)
                return
        raise KeyError(index)

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        self.active.apply(entry, offset)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        for e, _ in pairs:
            self.user_bytes += len(e.key) + len(e.value)
        self.active.apply_batch(pairs)

    def get(self, key: bytes) -> Optional[bytes]:
        return self.active.get(key)

    def scan(self, lo: bytes, hi: bytes):
        return self.active.scan(lo, hi)

    def recover(self):
        self.active.db.recover()
        entries, offsets = [], []
        # header-only: offsets suffice to replay the state machine
        for off, e in self.active.vlog.scan_headers():
            entries.append(e)
            offsets.append(off)
        return entries, offsets, 0, 0

    def load_full_entry(self, index: int, offset: int) -> LogEntry:
        return self.active.vlog.read_at(offset)

    def close(self):
        self.active.close()


class NezhaEngine(EngineBase):
    """Full Nezha: KVS-Raft + Raft-aware GC + three-phase request routing
    (paper Algorithms 1-3, Table I)."""
    name = "nezha"

    def __init__(self, dirpath, metrics=None, *, gc_threshold: int = 32 << 20,
                 gc_batch: int = 64, on_snapshot=None, **kw):
        super().__init__(dirpath, metrics, **kw)
        self.gc_threshold = gc_threshold
        self.gc_batch = gc_batch
        self.on_snapshot = on_snapshot  # callback(last_index, last_term)
        self.gen = 0
        self.active = StorageModule(dirpath, self.metrics,
                                    f"m{self.gen:04d}", sync=self.sync,
                                    group_commit=True, cache=self.cache)
        self.new: Optional[StorageModule] = None
        self.sorted: Optional[SortedStore] = None
        self.gc_started = False
        self.gc_completed = True  # no GC yet
        self.gc_count = 0
        self._state_path = os.path.join(dirpath, "gc_state.json")
        self._seg_of_index: Dict[int, str] = {}
        self._gc_iter: Optional[Iterator] = None
        self._gc_last: Tuple[int, int] = (0, 0)     # last APPLIED (idx, term)
        self._building: Optional[SortedStore] = None
        self._last_by_tag: Dict[str, Tuple[int, int]] = {}
        self._boundary: Tuple[int, int] = (0, 0)    # GC snapshot point

    # --------------------------------------------------------- log store
    def _write_module(self) -> StorageModule:
        return self.new if self.new is not None else self.active

    def append(self, entry: LogEntry) -> int:
        mod = self._write_module()
        off = mod.vlog.append(entry)
        self._seg_of_index[entry.index] = mod.tag
        self._last_by_tag[mod.tag] = (entry.index, entry.term)
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        if not entries:
            return []
        mod = self._write_module()
        offs = mod.vlog.append_batch(entries)      # ONE buffered write
        for e in entries:
            self._seg_of_index[e.index] = mod.tag
        last = entries[-1]
        self._last_by_tag[mod.tag] = (last.index, last.term)
        return offs

    def commit_window(self):
        self.active.sync_now()
        if self.new is not None:
            self.new.sync_now()

    def truncate_from(self, index: int):
        mod = self._write_module()
        assert self._seg_of_index.get(index) in (None, mod.tag), \
            "conflict truncation across GC segments is not supported"
        for off, e in mod.vlog.scan():
            if e.index == index:
                mod.vlog.truncate_to(off)
                return
        raise KeyError(index)

    def apply(self, entry: LogEntry, offset: int):
        self.user_bytes += len(entry.key) + len(entry.value)
        mod = self._module_of(entry.index)
        mod.apply(entry, offset)
        self._gc_last = (entry.index, entry.term)

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Group apply; a batch may straddle the Active->New rotation, so
        coalesce per consecutive-module run (order within the drain is
        preserved)."""
        run: List[Tuple[LogEntry, int]] = []
        run_mod = None
        for e, off in pairs:
            self.user_bytes += len(e.key) + len(e.value)
            mod = self._module_of(e.index)
            if mod is not run_mod and run:
                run_mod.apply_batch(run)
                run = []
            run_mod = mod
            run.append((e, off))
        if run:
            run_mod.apply_batch(run)
        last = pairs[-1][0]
        self._gc_last = (last.index, last.term)

    def _module_of(self, index: int) -> StorageModule:
        tag = self._seg_of_index.get(index)
        return self.new if (self.new is not None and tag == self.new.tag) \
            else self.active

    def load_full_entry(self, index: int, offset: int) -> LogEntry:
        return self._module_of(index).vlog.read_at(offset)

    # ------------------------------------------------------- three-phase
    def _chain(self) -> List:
        """Lookup sources, most-recent first (Algorithms 2 & 3)."""
        chain: List = []
        if self.new is not None:
            chain.append(self.new)
        chain.append(self.active)
        if self.sorted is not None:
            chain.append(self.sorted)
        return chain

    def get(self, key: bytes) -> Optional[bytes]:
        for src in self._chain():
            v = src.get(key)
            if v is not None:
                return v
        return None

    def scan(self, lo: bytes, hi: bytes):
        out: Dict[bytes, bytes] = {}
        for src in reversed(self._chain()):   # oldest first; newest wins
            for k, v in src.scan(lo, hi):
                out[k] = v
        return sorted(out.items())

    # ---------------------------------------------------------------- GC
    def post_op(self):
        if self.gc_started and not self.gc_completed:
            self.gc_step(self.gc_batch)
        elif self.active.vlog.size >= self.gc_threshold:
            self.start_gc()

    def start_gc(self):
        assert self.gc_completed, "GC already running"
        self.gc_started, self.gc_completed = True, False
        self.gc_count += 1
        # snapshot point = last entry PERSISTED into the active segment; the
        # compaction may only consume (and later drop) the active segment
        # once everything up to this point has committed+applied — Raft's
        # log-completeness is preserved (paper §III-E).
        self._boundary = self._last_by_tag.get(self.active.tag, (0, 0))
        self.gen += 1
        self.new = StorageModule(self.dir, self.metrics, f"m{self.gen:04d}",
                                 sync=self.sync, group_commit=True,
                                 cache=self.cache)
        self._building = SortedStore(self.dir, self.metrics, gen=self.gen,
                                     cache=self.cache)
        open(self._building.path, "wb").close()
        self._building._started = True
        with open(self._state_path, "w") as f:
            json.dump({"started": True, "complete": False, "gen": self.gen,
                       "last_index": self._boundary[0],
                       "last_term": self._boundary[1]}, f)
        self.metrics.on_write("gc_meta", 64)
        self._gc_snapshot_point = self._boundary
        self._gc_iter = None  # built once the boundary has been applied

    def _merged_items(self, resume_after: Optional[bytes] = None):
        """Key-ascending merge: live data of Active (via its index, already
        deduped+sorted) with the previous sorted store."""
        act = iter(self.active.sorted_items())
        old = iter(self.sorted.items()) if self.sorted is not None else iter(())
        a = next(act, None)
        o = next(old, None)
        while a is not None or o is not None:
            if o is None or (a is not None and a[0] <= o[0]):
                key, off = a
                if o is not None and o[0] == key:
                    o = next(old, None)          # active version wins
                entry = self.active.vlog.read_at(off)  # scattered GC read
                yield key, entry
                a = next(act, None)
            else:
                yield o
                o = next(old, None)

    def gc_step(self, n: int):
        """Advance compaction by n entries; requests interleave freely."""
        if self._gc_iter is None:
            # barrier: wait until the whole active segment has applied
            if self._gc_last[0] < self._gc_snapshot_point[0]:
                return
            self._gc_iter = self._merged_items()
        buf = []
        done = False
        for _ in range(n):
            item = next(self._gc_iter, None)
            if item is None:
                done = True
                break
            buf.append(item)
        if buf:
            li, lt = self._gc_snapshot_point
            # append-mode build (incremental)
            mode_resume = getattr(self._building, "_started", False)
            self._building._started = True
            with open(self._building.path, "ab" if mode_resume else "wb") as f:
                off = f.tell()
                for key, entry in buf:
                    data = entry.encode()
                    f.write(data)
                    self.metrics.on_write("gc_sorted", len(data))
                    self._building.index[key] = (off, len(data))
                    self._building.keys.append(key)
                    off += len(data)
        if done:
            self.finish_gc()

    def finish_gc(self):
        li, lt = self._gc_snapshot_point
        self._building.last_index = li
        self._building.last_term = lt
        self._building._complete = True
        with open(self._building.meta_path, "w") as f:
            json.dump({"last_index": li, "last_term": lt, "complete": True}, f)
        old_sorted = self.sorted
        self.sorted = self._building
        self._building = None
        self._gc_iter = None
        # cleanup phase: drop expired Active files (+ previous sorted gen)
        self.active.destroy()
        if old_sorted is not None:
            old_sorted.destroy()
        # role rotation: New becomes Active
        self.active = self.new
        self.new = None
        self.gc_completed = True
        with open(self._state_path, "w") as f:
            json.dump({"started": True, "complete": True, "gen": self.gen,
                       "last_index": li, "last_term": lt}, f)
        self.metrics.on_write("gc_meta", 64)
        if self.on_snapshot is not None:
            self.on_snapshot(li, lt)

    def run_gc_to_completion(self):
        while self.gc_started and not self.gc_completed:
            self.gc_step(1024)

    # ----------------------------------------------------------- recovery
    def recover(self):
        state = {}
        if os.path.exists(self._state_path):
            with open(self._state_path) as f:
                state = json.load(f)
        gen = state.get("gen", 0)
        if state.get("started") and not state.get("complete"):
            # crashed mid-GC: resume from the interrupt point (§III-E)
            self.gen = gen
            prev = SortedStore(self.dir, self.metrics, gen=gen - 1,
                               cache=self.cache)
            self.sorted = prev if prev.load() else None
            self.active = StorageModule(self.dir, self.metrics,
                                        f"m{gen - 1:04d}", sync=self.sync,
                                        group_commit=True, cache=self.cache)
            self.active.db.recover()
            self.new = StorageModule(self.dir, self.metrics,
                                     f"m{gen:04d}", sync=self.sync,
                                     group_commit=True, cache=self.cache)
            self.new.db.recover()
            self._building = SortedStore(self.dir, self.metrics, gen=gen,
                                         cache=self.cache)
            resume_key = self._building.last_key_on_disk()
            self._building._started = resume_key is not None
            if resume_key is not None:  # reload partial index
                self._building.index.clear()
                self._building.keys = []
                with open(self._building.path, "rb") as f:
                    buf = f.read()
                off = 0
                while off < len(buf):
                    e, nxt = LogEntry.decode(buf, off)
                    self._building.index[e.key] = (off, nxt - off)
                    self._building.keys.append(e.key)
                    off = nxt
            self.gc_started, self.gc_completed = True, False
            self._gc_snapshot_point = (state["last_index"],
                                       state["last_term"])
            self._boundary = self._gc_snapshot_point
            self._gc_last = (0, 0)  # re-applied by raft replay after restart
            if resume_key is not None:
                # compaction had begun => the barrier had passed pre-crash
                # and the active db was WAL-recovered: resume immediately
                # after the interrupt point (paper §III-E).
                self._gc_last = self._gc_snapshot_point
                full = self._merged_items()
                self._gc_iter = (x for x in full if x[0] > resume_key)
            else:
                self._gc_iter = None  # barrier re-evaluated in gc_step
        else:
            self.gen = gen
            cur = SortedStore(self.dir, self.metrics, gen=gen,
                              cache=self.cache)
            self.sorted = cur if cur.load() else None
            self.active = StorageModule(self.dir, self.metrics,
                                        f"m{gen:04d}", sync=self.sync,
                                        group_commit=True, cache=self.cache)
            self.active.db.recover()
            self.new = None
            self.gc_started = bool(state.get("started"))
            self.gc_completed = True
            if self.sorted is not None:
                self._gc_last = (self.sorted.last_index,
                                 self.sorted.last_term)
        # rebuild raft tail from the live vlogs — HEADER-ONLY scan: the
        # KVS-Raft state machine replays (key, offset), never values
        # (the paper's Fig. 11 recovery win).  Values hydrate lazily via
        # load_full_entry when the node must replicate old entries.
        entries, offsets = [], []
        mods = [self.active] + ([self.new] if self.new else [])
        for mod in mods:
            for off, e in mod.vlog.scan_headers():
                entries.append(e)
                offsets.append(off)
                self._seg_of_index[e.index] = mod.tag
        si, st = (self.sorted.last_index, self.sorted.last_term) \
            if self.sorted is not None else (0, 0)
        entries = [e for e in entries if e.index > si]
        offsets = offsets[-len(entries):] if entries else []
        return entries, offsets, si, st

    # ----------------------------------------------------------- snapshot
    def snapshot(self):
        if self.sorted is None:
            return None
        return (self.sorted.last_index, self.sorted.last_term,
                self.sorted.snapshot_payload())

    def install_snapshot(self, last_index: int, last_term: int, payload):
        # A shipped snapshot supersedes everything local: abort any local GC
        # and reset the mutable modules (Raft discards the whole local log
        # before installing, so active/new hold only superseded entries).
        if self._building is not None:
            self._building.destroy()
            self._building = None
        self._gc_iter = None
        self.gc_started, self.gc_completed = False, True
        if self.new is not None:
            self.new.destroy()
            self.new = None
        self.active.destroy()
        self._seg_of_index.clear()
        self.gen += 1
        self.active = StorageModule(self.dir, self.metrics,
                                    f"m{self.gen:04d}", sync=self.sync,
                                    group_commit=True, cache=self.cache)
        store = SortedStore(self.dir, self.metrics, gen=self.gen,
                            cache=self.cache)
        store.install_payload(payload, last_index, last_term)
        old = self.sorted
        self.sorted = store
        if old is not None:
            old.destroy()
        self._gc_last = (last_index, last_term)
        with open(self._state_path, "w") as f:
            json.dump({"started": False, "complete": True, "gen": self.gen,
                       "last_index": last_index, "last_term": last_term}, f)

    def close(self):
        self.active.close()
        if self.new is not None:
            self.new.close()


ENGINES = {
    "original": OriginalEngine,
    "pasv": PASVEngine,
    "dwisckey": DwisckeyEngine,
    "lsmraft": LSMRaftEngine,
    "tikv": OriginalEngine,       # paper: TiKV follows the Original design
    "nezha_nogc": NezhaNoGCEngine,
    "nezha": NezhaEngine,
}
