"""Raft consensus (arXiv 1409.585 / Ongaro & Ousterhout 2014), deterministic
and storage-pluggable.

The persistence hook is the point of the paper: ``log_store.append(entry)``
is invoked exactly once per log entry, BEFORE the entry is acknowledged, and
returns the byte offset of the persisted record.  In KVS-Raft the log store
is the ValueLog itself, so that single append persists the value, and the
state machine receives (entry, offset) at apply time — storing only the
offset (paper Algorithm 1).

Safety-property surface tested by tests/test_raft_properties.py:
  Election Safety, Log Matching, Leader Completeness, State Machine Safety.

Read path (client.py's consistency tiers ride on these primitives):

  * ReadIndex (§6.4): `read_index_submit()` records the commit index and
    queues a ReadHandle; the next tick starts ONE heartbeat-quorum round
    (a `probe` sequence number piggybacked on AppendEntries and echoed in
    the reply) that confirms leadership for EVERY read queued at that
    moment.  A handle turns `ready` once confirmed and applied >= its
    read index; losing leadership turns it `aborted` instead — a deposed
    leader can never serve a possibly-stale linearizable read.
  * Leader lease: every probe ack also carries evidence the follower
    still accepted us as leader at the probe's SEND time; when a majority
    (incl. self) acked probes sent at time t, the lease extends to
    t + lease_ticks.  `lease_valid()` then authorizes local reads with no
    quorum round.  Soundness rests on two legs: lease_ticks <
    min(election_timeout), and leader stickiness — a node disregards
    RequestVote within min(election_timeout) of valid leader traffic
    (§9.6), so the followers renewing a lease can never simultaneously
    form the majority that elects the leader's replacement.

Membership (single-server changes, thesis §4):

  * The configuration = (voters, learners) rides in the log as KIND_CONFIG
    entries.  A config is EFFECTIVE ON APPEND — leader and followers adopt
    it the moment it lands in their log — and commits under its own quorum
    (the new voter set).  Only one voter add/remove per entry and at most
    one config change in flight (propose_config refuses while the previous
    one is uncommitted): adjacent configs then always share a majority, so
    two disjoint quorums can never form.
  * Learners replicate (AppendEntries / InstallSnapshot / run shipping)
    but never vote, campaign, or count toward any quorum.  The leader
    tracks each peer's applied index from replies and auto-promotes a
    learner once it has applied the config that added it AND is within
    `promote_lag` of the leader's commit index.
  * A voter refuses RequestVote from any candidate outside its current
    voter set — a removed node's runaway term cannot disturb the live
    quorum.  Graceful leader removal: `transfer_leadership()` sends
    TimeoutNow to the best-caught-up voter, whose transfer-flagged
    election bypasses leader stickiness; the old leader's lease is killed
    at send time so LEASE reads can't straddle the handoff.
  * Truncating a log suffix rolls the config back to the newest surviving
    entry; snapshots carry the config at their last index.

Durability contract (see engines.py for the full statement): this module
itself performs no file I/O — everything durable flows through the log
store.  The two commitments Raft relies on are (a) `commit_window()` is
called before any ack/commit ("durable before ack" below), so an acked
entry is on disk at every crash point the FaultFS sweep can inject, and
(b) `persist_meta()` lands term/vote — and since PR 8 the adopted
config — atomically, so kill -9 can never resurrect a pre-vote term,
double-grant a vote, or forget a membership the node acted on.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import trace as _trace
from repro.core.simnet import SimNet
from repro.core.valuelog import KIND_CONFIG, KIND_NOOP, KIND_PUT, LogEntry

FOLLOWER, CANDIDATE, LEADER = "follower", "candidate", "leader"

_NEVER = -(10 ** 9)


# ------------------------------------------------------------------ messages
@dataclass
class RequestVote:
    term: int
    candidate: int
    last_log_index: int
    last_log_term: int
    # set on elections started by TimeoutNow: an explicit leadership
    # transfer must override the receivers' leader stickiness (§3.10)
    transfer: bool = False


@dataclass
class RequestVoteReply:
    term: int
    granted: bool


@dataclass
class AppendEntries:
    term: int
    leader: int
    prev_log_index: int
    prev_log_term: int
    entries: List[LogEntry]
    leader_commit: int
    # ReadIndex/lease piggyback: the leader's heartbeat-quorum round id.
    # A follower echoes it in its reply; any reply (success or not) in the
    # leader's term proves the follower still accepted its leadership when
    # this round was SENT — which is exactly what ReadIndex confirmation
    # and lease renewal need.  0 = no round attached (legacy traffic).
    probe: int = 0
    # trace context: span id of the newest client op whose entry rides in
    # this batch (repro.core.trace), so follower-side durability work
    # grafts onto the originating op's span tree.  0 = no context.
    ctx: int = 0


@dataclass
class AppendEntriesReply:
    term: int
    success: bool
    match_index: int
    probe: int = 0    # echo of AppendEntries.probe
    applied: int = 0  # follower's last_applied — drives learner promotion
    ctx: int = 0      # echo of AppendEntries.ctx (trace context)


@dataclass
class ReadHandle:
    """One pending consistency-tiered read on the leader (client.py).

    Lifecycle: submitted (probe=None) -> assigned to the next quorum round
    (probe=round id) -> `confirmed` when a majority echoed that round ->
    `ready` once last_applied >= read_index.  `aborted` is terminal: the
    node lost leadership (or the client timed it out) before confirmation,
    so serving would risk a stale read."""
    read_index: int
    probe: Optional[int] = None
    confirmed: bool = False
    ready: bool = False
    aborted: bool = False


@dataclass
class InstallSnapshot:
    term: int
    leader: int
    last_index: int
    last_term: int
    payload: Any  # engine-defined snapshot blob (e.g. sorted ValueLog bytes)
    # membership as of last_index — a fresh learner's very first state
    # arrives this way, so the snapshot must carry the config too
    config_index: int = 0
    voters: Tuple[int, ...] = ()
    learners: Tuple[int, ...] = ()
    ctx: int = 0      # trace context of the shipping leader's span


@dataclass
class InstallSnapshotReply:
    term: int
    match_index: int
    ctx: int = 0      # echo of InstallSnapshot.ctx


@dataclass
class TimeoutNow:
    """Leadership transfer (§3.10): the leader tells the best-caught-up
    voter to start an election immediately, stickiness notwithstanding."""
    term: int
    leader: int
    ctx: int = 0      # trace context of the transfer decision


@dataclass
class ShipRun:
    """One chunk of a run-adoption record (leader-driven GC replication).

    `rec` is the adoption record metadata built by the engine when it seals
    a run: kind ('flush'|'merge'), level, (last_index, last_term) run
    boundary, boundary_before/boundary store boundaries, retire identities,
    pos=(leader term, ship epoch), size and nchunks.  Chunks are resumable:
    the follower acks its contiguous prefix and the leader retransmits from
    there, so crashes/partitions/drops mid-ship never lose the record."""
    term: int
    leader: int
    rec: dict
    seq: int          # chunk number, 0-based
    data: bytes


@dataclass
class ShipRunReply:
    term: int
    pos: Tuple[int, int]      # record this reply refers to
    have: int                 # contiguous chunks buffered for that record
    adopted: Tuple[int, int]  # follower's durable ship position
    resync: bool = False      # fence tripped: please InstallSnapshot me


class LogStoreBase:
    """Persistence interface the engines implement."""

    def append(self, entry: LogEntry) -> int:
        raise NotImplementedError

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        """Group-commit hook: persist a whole batch with one buffered write.
        Engines override to coalesce; the default just loops."""
        return [self.append(e) for e in entries]

    def commit_window(self):
        """Group-commit boundary: make everything appended/applied since the
        last call durable with at most one fsync per underlying file.
        Called by Raft BEFORE acknowledging a batch.  Default: no-op."""

    def truncate_from(self, index: int):
        raise NotImplementedError

    def persist_meta(self, term: int, voted_for: Optional[int],
                     config: Optional[dict] = None):
        pass


class RaftNode:
    def __init__(self, nid: int, peers: List[int], net: SimNet,
                 log_store: LogStoreBase,
                 apply_fn: Callable[[LogEntry, int], None],
                 apply_batch_fn: Optional[
                     Callable[[List[Tuple[LogEntry, int]]], None]] = None,
                 *, seed: int = 0,
                 election_timeout: Tuple[int, int] = (20, 40),
                 heartbeat_every: int = 5,
                 max_entries_per_rpc: int = 64,
                 max_batch: Optional[int] = None,
                 lease_ticks: Optional[int] = None,
                 snapshot_fn: Optional[Callable[[], Optional[Tuple[int, int, Any]]]] = None,
                 install_snapshot_fn: Optional[Callable[[int, int, Any], None]] = None,
                 voters: Optional[List[int]] = None,
                 learners: Optional[List[int]] = None,
                 promote_lag: int = 16,
                 auto_promote: bool = True,
                 group: Optional[int] = None):
        self.nid = nid
        # Multi-Raft: `group` names the shard consensus group this node
        # belongs to.  The protocol below is entirely group-oblivious —
        # nid/peers/quorum stay small local ints — and only the NETWORK
        # boundary translates to the shared SimNet's wire address
        # (group, nid), so many independent groups multiplex over one
        # fabric (see repro/core/shards.py).  group=None keeps the
        # original single-group addressing byte-for-byte.
        self.group = group
        # membership: by default every constructor peer (plus self) is a
        # voter; explicit voters/learners model a node joining an existing
        # cluster (a fresh learner, a restarted member).  self.peers is
        # always derived from the current config = all members minus self.
        if voters is None:
            voters = sorted(set(peers) | {nid})
        self._configs: List[Tuple[int, Tuple[int, ...], Tuple[int, ...]]] = \
            [(0, tuple(sorted(set(voters))),
              tuple(sorted(set(learners or ()) - set(voters))))]
        self.voters: set = set()
        self.learners: set = set()
        self.peers: List[int] = []
        self._set_config()
        self.promote_lag = promote_lag
        self.auto_promote = auto_promote
        self.peer_applied: Dict[int, int] = {}
        self._transfer_until = _NEVER
        self.net = net
        self.store = log_store
        self.apply_fn = apply_fn
        self.apply_batch_fn = apply_batch_fn
        self.snapshot_fn = snapshot_fn
        self.install_snapshot_fn = install_snapshot_fn
        self.rng = random.Random(seed * 7919 + nid)
        self.eto = election_timeout
        self.heartbeat_every = heartbeat_every
        # max_batch governs BOTH entries-per-AppendEntries and the
        # group-commit window (one fsync per window, see client_put_many);
        # max_entries_per_rpc is its default when unset
        self.max_batch = max_batch if max_batch is not None \
            else max_entries_per_rpc
        # leader lease duration; must stay under min(election_timeout) —
        # vote stickiness only shields that long, so a bigger lease would
        # let a rival leader be elected while the old lease reads valid
        self.lease_ticks = lease_ticks if lease_ticks is not None \
            else max(1, election_timeout[0] - heartbeat_every)
        if self.lease_ticks >= election_timeout[0]:
            # correctness invariant, not a debug check (asserts vanish
            # under python -O): an oversized lease outlives the vote-
            # stickiness window and re-opens the stale-lease-read hole
            raise ValueError(
                f"lease_ticks={self.lease_ticks} must stay under the "
                f"minimum election timeout {election_timeout[0]} "
                "(lease safety)")

        self.current_term = 0
        self.voted_for: Optional[int] = None
        # in-memory log: entries[i] covers raft index snap_index + 1 + i
        self.entries: List[LogEntry] = []
        self.offsets: List[int] = []
        self.snap_index = 0
        self.snap_term = 0

        self.role = FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.leader_id: Optional[int] = None
        self.next_index: Dict[int, int] = {}
        self.match_index: Dict[int, int] = {}
        self.votes: set = set()
        # run-shipping endpoints (wired by the cluster when the engine has
        # run_shipping enabled): the leader's RunShipper streams sealed-run
        # chunks, the follower's RunAdopter assembles + installs them
        self.shipper = None
        self.adopter = None
        # ReadIndex / lease state (leader-only; see module docstring).
        # _probe_sent maps round id -> send time; _probe_acked / _ack_basis
        # track, per peer, the newest round echoed and the send time of
        # that round (the lease basis).  metrics is wired by the cluster
        # so quorum rounds triggered by reads are byte-counter evidence.
        self.pending_reads: List[ReadHandle] = []
        self.lease_until = _NEVER
        self.metrics = None
        # last time valid leader traffic arrived (AppendEntries /
        # InstallSnapshot / ShipRun in a current term) — the basis for
        # leader stickiness in _on_request_vote, which is what makes the
        # lease sound: no majority can form inside a live leader's lease
        self._last_leader_contact = _NEVER
        self._probe_seq = 0
        self._probe_sent: Dict[int, int] = {}
        self._probe_acked: Dict[int, int] = {}
        self._ack_basis: Dict[int, int] = {}
        self._term_start_index = 0
        self._reset_election_deadline()
        self._next_heartbeat = 0
        # metrics for tests
        self.applied_log: List[Tuple[int, LogEntry]] = []
        self.leadership_history: List[Tuple[int, int]] = []

    # --------------------------------------------------- address plumbing
    @property
    def addr(self):
        """This node's wire address on the SimNet: the bare local id when
        ungrouped, (group, nid) when part of a shard group.  Trace events
        are keyed by addr too, so the causality auditor's per-node state
        is naturally per-group — no cross-group false positives."""
        return self.nid if self.group is None else (self.group, self.nid)

    def _addr(self, peer: int):
        return peer if self.group is None else (self.group, peer)

    def _local(self, src) -> int:
        """Incoming wire address -> local peer id (intra-group only)."""
        return src if self.group is None else src[1]

    def _send(self, dst: int, msg, size: int = 0):
        self.net.send(self.addr, self._addr(dst), msg, size=size)

    # ------------------------------------------------------------- helpers
    def _reset_election_deadline(self):
        self.election_deadline = self.net.time + self.rng.randint(*self.eto)

    @property
    def last_log_index(self) -> int:
        return self.snap_index + len(self.entries)

    def term_at(self, index: int) -> int:
        if index == self.snap_index:
            return self.snap_term
        if index < self.snap_index or index > self.last_log_index:
            return -1
        return self.entries[index - self.snap_index - 1].term

    def entry_at(self, index: int) -> LogEntry:
        return self.entries[index - self.snap_index - 1]

    def _hydrated(self, index: int) -> LogEntry:
        """Lazy-value recovery support: entries restored via header-only
        scans carry value=b'' and are re-read from the log store before
        being replicated to a follower."""
        e = self.entry_at(index)
        if getattr(e, "value_len", 0) and not e.value and \
                hasattr(self.store, "load_full_entry"):
            off = self.offsets[index - self.snap_index - 1]
            full = self.store.load_full_entry(index, off)
            self.entries[index - self.snap_index - 1] = full
            return full
        return e

    def _persist_meta(self):
        self.store.persist_meta(self.current_term, self.voted_for,
                                config=self._meta_config())

    # -------------------------------------------------------- membership
    @property
    def config_index(self) -> int:
        return self._configs[-1][0]

    @property
    def is_voter(self) -> bool:
        return self.nid in self.voters

    def _meta_config(self) -> dict:
        """The config recovery may take as its BASE: the newest one that
        is committed (or snapshot-covered).  An uncommitted config is
        recovered from its durable log entry instead — persisting it as
        the base would make it impossible to roll back after a restart
        when the new leader truncates the suffix that carried it."""
        idx, v, l = self._config_at(max(self.commit_index, self.snap_index))
        return {"index": idx, "voters": list(v), "learners": list(l)}

    def _set_config(self):
        """Derive live membership state from the newest config entry."""
        _, v, l = self._configs[-1]
        self.voters = set(v)
        self.learners = set(l)
        self.peers = sorted((self.voters | self.learners) - {self.nid})

    def _quorum(self, count: int) -> bool:
        return count * 2 > len(self.voters)

    def _config_at(self, index: int) -> Tuple[int, Tuple[int, ...],
                                              Tuple[int, ...]]:
        """Newest config entry at or below `index` (for snapshots)."""
        best = self._configs[0]
        for c in self._configs:
            if c[0] <= index:
                best = c
        return best

    def _apply_config_change(self):
        """A config was adopted or rolled back: refresh derived state,
        persist it, and (on the leader) resize replication bookkeeping."""
        self._set_config()
        self._persist_meta()
        if self.role == LEADER:
            for p in self.peers:
                self.next_index.setdefault(p, self.last_log_index + 1)
                self.match_index.setdefault(p, 0)
            gone = (set(self.next_index) | set(self.match_index)) \
                - set(self.peers) - {self.nid}
            for g in gone:
                self.next_index.pop(g, None)
                self.match_index.pop(g, None)
                self._probe_acked.pop(g, None)
                self._ack_basis.pop(g, None)
                self.peer_applied.pop(g, None)
            if self.shipper is not None:
                self.shipper.sync_peers()

    def _adopt_config_entry(self, e: LogEntry):
        """Effective on append: the entry's config governs immediately."""
        cfg = json.loads(bytes(e.value).decode())
        self._configs = [c for c in self._configs if c[0] < e.index]
        self._configs.append((e.index, tuple(cfg["voters"]),
                              tuple(cfg["learners"])))
        self._apply_config_change()
        if self.metrics is not None:
            self.metrics.on_membership("config_adopted")

    def _rollback_configs(self, from_index: int):
        """A log suffix was truncated: fall back to the newest config that
        survived (the base entry — snapshot- or meta-backed — always
        does)."""
        if self._configs[-1][0] >= from_index and len(self._configs) > 1:
            self._configs = [self._configs[0]] + \
                [c for c in self._configs[1:] if c[0] < from_index]
            self._apply_config_change()

    def restore_config(self, meta_config: Optional[dict]):
        """Recovery: rebuild the config history from the persisted meta
        base plus any KIND_CONFIG entries surviving in the recovered log
        (persist_meta is ordered after the log append, so the log can run
        ahead of the meta but never behind it)."""
        if meta_config and meta_config.get("voters"):
            base = (int(meta_config.get("index", 0)),
                    tuple(meta_config["voters"]),
                    tuple(meta_config.get("learners", ())))
        else:
            base = self._configs[0]
        cfgs = [base]
        for e in self.entries:
            if e.kind != KIND_CONFIG:
                continue
            e = self._hydrated(e.index)
            if e.index > cfgs[-1][0]:
                cfg = json.loads(bytes(e.value).decode())
                cfgs.append((e.index, tuple(cfg["voters"]),
                             tuple(cfg["learners"])))
        self._configs = cfgs
        self._set_config()

    def propose_config(self, voters, learners) -> Optional[int]:
        """Leader-only single-server membership change.  Refused while the
        previous config entry is uncommitted (at most one in flight) and
        for multi-voter jumps (adjacent configs must share a majority)."""
        if self.role != LEADER:
            return None
        if self._configs[-1][0] > self.commit_index:
            return None                      # one change in flight, max
        voters = tuple(sorted(set(voters)))
        learners = tuple(sorted(set(learners) - set(voters)))
        cur_v = tuple(sorted(self.voters))
        cur_l = tuple(sorted(self.learners))
        if (voters, learners) == (cur_v, cur_l):
            return self.config_index         # no-op: already in effect
        if len(set(voters) ^ set(cur_v)) > 1:
            raise ValueError("only single-server voter changes are safe "
                             f"({cur_v} -> {voters})")
        payload = json.dumps({"voters": list(voters),
                              "learners": list(learners)}).encode()
        entry = LogEntry(self.current_term, self.last_log_index + 1,
                         KIND_CONFIG, b"", payload)
        off = self.store.append(entry)
        self.store.commit_window()           # durable before ack
        if _trace._ACTIVE is not None:
            _trace._ACTIVE.event("durable", self.addr, entry.index)
        self.entries.append(entry)
        self.offsets.append(off)
        self.match_index[self.nid] = self.last_log_index
        self._adopt_config_entry(entry)      # effective on append
        if self.metrics is not None:
            self.metrics.on_membership("config_proposed")
        self._advance_commit()
        self._broadcast_append()
        self._next_heartbeat = self.net.time + self.heartbeat_every
        return entry.index

    def propose_add_learner(self, nid: int) -> Optional[int]:
        if nid in self.voters or nid in self.learners:
            return self.config_index
        return self.propose_config(self.voters, set(self.learners) | {nid})

    def propose_promote(self, nid: int) -> Optional[int]:
        if nid in self.voters:
            return self.config_index
        if nid not in self.learners:
            return None
        return self.propose_config(set(self.voters) | {nid},
                                   set(self.learners) - {nid})

    def propose_remove(self, nid: int) -> Optional[int]:
        if nid not in self.voters and nid not in self.learners:
            return self.config_index
        return self.propose_config(set(self.voters) - {nid},
                                   set(self.learners) - {nid})

    def _maybe_promote(self):
        """Leader tick: promote the first learner whose applied index has
        caught up — it must have applied the config that added it AND sit
        within promote_lag of our commit index."""
        if self.role != LEADER or not self.auto_promote or not self.learners:
            return
        if self._configs[-1][0] > self.commit_index:
            return                           # a change is already in flight
        for lid in sorted(self.learners):
            ap = self.peer_applied.get(lid, _NEVER)
            if ap >= self.config_index and \
                    ap + self.promote_lag >= self.commit_index:
                if self.propose_promote(lid) is not None and \
                        self.metrics is not None:
                    self.metrics.on_membership("promote")
                return

    def transfer_leadership(self, to: Optional[int] = None) -> Optional[int]:
        """Graceful handoff: pick the best-caught-up voter (unless told),
        kill our own lease so no LEASE read straddles the change, and send
        TimeoutNow.  We keep leading until the target's election deposes
        us; if it never does, leases resume after one election timeout."""
        if self.role != LEADER:
            return None
        cands = [v for v in self.voters if v != self.nid]
        if not cands:
            return None
        if to is None or to not in cands:
            to = max(cands, key=lambda p: (self.match_index.get(p, 0), -p))
        self._transfer_until = self.net.time + self.eto[0]
        self._abort_reads()                  # lease dies at send time
        t = _trace._ACTIVE
        self._send(to, TimeoutNow(
            self.current_term, self.nid,
            ctx=t.current() if t is not None else 0))
        if self.metrics is not None:
            self.metrics.on_membership("transfer")
        return to

    def _on_timeout_now(self, src: int, m: TimeoutNow):
        if m.term < self.current_term:
            return
        if m.term > self.current_term:
            self._become_follower(m.term)
        if self.role == LEADER or self.nid not in self.voters:
            return
        self._last_leader_contact = _NEVER   # the leader ASKED for this
        t = _trace._ACTIVE
        sid = t.begin("timeout_now", kind="raft", node=self.addr,
                      parent=m.ctx,
                      old_leader=self._addr(src)) if t is not None else None
        self._start_election(transfer=True)
        if sid is not None:
            t.end(sid)

    def _step_down(self):
        """We led a cluster we are no longer a voter of and the removal
        config just committed: stop leading (keep term and vote — clearing
        voted_for inside a term could double-grant)."""
        self.role = FOLLOWER
        self.leader_id = None
        self._abort_reads()
        self._reset_election_deadline()

    def _become_follower(self, term: int):
        self.current_term = term
        self.role = FOLLOWER
        self.voted_for = None
        self.votes = set()
        self._abort_reads()   # a deposed leader must refuse pending reads
        self._persist_meta()
        # NOTE: no election-deadline reset here.  The timer resets only on
        # granting a vote or on valid leader traffic (AppendEntries /
        # InstallSnapshot / ShipRun); a bare term bump must not — otherwise
        # a disruptive candidate with a stale log and a short timeout can
        # reset everyone forever and no electable node ever stands.

    # ------------------------------------------------------- read tiers
    def _abort_reads(self):
        """Leadership is gone (or never confirmed): every queued read is
        refused rather than risk serving stale state, and the lease dies."""
        for h in self.pending_reads:
            h.aborted = True
        self.pending_reads = []
        self.lease_until = _NEVER
        self._probe_acked = {}
        self._ack_basis = {}

    def read_index_submit(self) -> Optional[ReadHandle]:
        """LINEARIZABLE tier: queue a ReadIndex read.  The read index is
        the current commit index, floored at this term's no-op barrier —
        before the barrier commits the leader cannot know its commit index
        is up to date (Raft §8 / §6.4).  One heartbeat-quorum round on the
        next tick confirms leadership for the whole queue."""
        if self.role != LEADER:
            return None
        return_index = max(self.commit_index, self._term_start_index)
        h = ReadHandle(read_index=return_index)
        self.pending_reads.append(h)
        return h

    def lease_valid(self) -> bool:
        """LEASE tier: may this node serve a local read with no quorum
        round right now?  Requires leadership, the term barrier committed
        (same reason as ReadIndex), and — with peers — a lease renewed by
        a recent heartbeat-quorum ack basis."""
        if self.role != LEADER or self.commit_index < self._term_start_index:
            return False
        if self.nid not in self.voters or \
                self.net.time < self._transfer_until:
            # a demoted leader, or one mid-transfer, must not serve local
            # reads — its replacement may already be elected
            return False
        voter_peers = [v for v in self.voters if v != self.nid]
        return not voter_peers or self.net.time < self.lease_until

    def _refresh_lease(self):
        """Lease = (send time of the newest probe a MAJORITY of VOTERS has
        acked, self included) + lease_ticks.  Sort voter ack bases
        descending and take the quorum-th: every node in that set accepted
        our leadership no earlier than that instant."""
        voter_peers = [v for v in self.voters if v != self.nid]
        if not voter_peers or self.net.time < self._transfer_until:
            return
        bases = sorted((self._ack_basis.get(p, _NEVER)
                        for p in voter_peers), reverse=True)
        # voters needed beyond self (self only counts if still a voter)
        need = len(self.voters) // 2 + 1 \
            - (1 if self.nid in self.voters else 0)
        basis = bases[need - 1] if need >= 1 else self.net.time
        if basis > _NEVER:
            self.lease_until = max(self.lease_until,
                                   basis + self.lease_ticks)

    def _dispatch_read_round(self):
        """Assign every not-yet-assigned pending read to ONE fresh
        heartbeat round — the batching that makes ReadIndex cheap: a
        queue of N reads costs one quorum round, not N."""
        if not any(h.probe is None for h in self.pending_reads):
            return False
        self._broadcast_append()
        for h in self.pending_reads:
            if h.probe is None:
                h.probe = self._probe_seq
        if self.metrics is not None:
            self.metrics.on_read_quorum_round()
        self._check_read_quorum()   # single-node: quorum of 1, instantly
        return True

    def _check_read_quorum(self):
        for h in self.pending_reads:
            if h.probe is not None and not h.confirmed:
                acks = sum(1 for v in self.voters
                           if v == self.nid or
                           self._probe_acked.get(v, 0) >= h.probe)
                if self._quorum(acks):
                    h.confirmed = True
        self._serve_ready_reads()

    def _serve_ready_reads(self):
        keep = []
        for h in self.pending_reads:
            if h.confirmed and self.last_applied >= h.read_index:
                h.ready = True
            elif not h.aborted:
                keep.append(h)
        self.pending_reads = keep

    # ------------------------------------------------------------ client
    def client_put(self, key: bytes, value: bytes) -> Optional[int]:
        """Leader-only. Appends + persists once; returns the raft index."""
        if self.role != LEADER:
            return None
        entry = LogEntry(self.current_term, self.last_log_index + 1,
                         KIND_PUT, key, value)
        t = _trace._ACTIVE
        sid = t.begin("raft.append", kind="raft", node=self.addr,
                      index=entry.index) if t is not None else None
        off = self.store.append(entry)           # THE single persistence
        self.store.commit_window()               # durable before ack
        if t is not None:
            t.event("durable", self.addr, entry.index)
            t.register_index(entry.index, group=self.group)
            t.end(sid)
        self.entries.append(entry)
        self.offsets.append(off)
        self.match_index[self.nid] = self.last_log_index
        self._advance_commit()   # single-voter configs self-commit here
        return entry.index

    def client_put_many(self, items: List[Tuple[bytes, bytes]]
                        ) -> Optional[List[int]]:
        """Leader-only group commit: the whole batch is persisted with one
        buffered write + one fsync per store (append_batch/commit_window),
        then shipped to followers immediately in max_batch-sized
        AppendEntries instead of waiting for the next heartbeat."""
        if self.role != LEADER:
            return None
        entries = []
        base = self.last_log_index
        for i, (key, value) in enumerate(items):
            entries.append(LogEntry(self.current_term, base + 1 + i,
                                    KIND_PUT, key, value))
        t = _trace._ACTIVE
        sid = t.begin("raft.append_batch", kind="raft", node=self.addr,
                      n=len(entries)) if t is not None else None
        offs = self.store.append_batch(entries)  # ONE persistence pass
        self.store.commit_window()               # ONE fsync per store
        if t is not None:
            t.event("durable", self.addr, entries[-1].index if entries
                    else base)
            for e in entries:
                t.register_index(e.index, group=self.group)
            t.end(sid)
        self.entries.extend(entries)
        self.offsets.extend(offs)
        self.match_index[self.nid] = self.last_log_index
        self._advance_commit()   # single-voter configs self-commit here
        # eager dispatch: a full window should not wait for the heartbeat
        self._broadcast_append()
        self._next_heartbeat = self.net.time + self.heartbeat_every
        return [e.index for e in entries]

    # -------------------------------------------------------------- tick
    def tick(self):
        if self.addr in self.net.down:
            return
        for src, msg in self.net.deliver(self.addr):
            self._handle(self._local(src), msg)
        now = self.net.time
        if self.role == LEADER:
            # a queued ReadIndex batch rides its own round immediately
            # (read latency should not wait for the heartbeat timer); the
            # round doubles as the heartbeat
            if self._dispatch_read_round():
                self._next_heartbeat = now + self.heartbeat_every
            elif now >= self._next_heartbeat:
                self._broadcast_append()
                self._next_heartbeat = now + self.heartbeat_every
            if self.shipper is not None:
                self.shipper.tick()
            self._maybe_promote()
        elif self.nid in self.voters and now >= self.election_deadline:
            # learners and removed nodes never campaign
            self._start_election()
        self._apply_committed()
        if self.role == LEADER:
            self._serve_ready_reads()
        if self.adopter is not None and self.role != LEADER:
            self.adopter.tick()   # install pending records once applied

    # ---------------------------------------------------------- election
    def _vote_quorum(self) -> bool:
        return self._quorum(len(self.votes & self.voters))

    def _start_election(self, transfer: bool = False):
        if self.nid not in self.voters:
            return                       # a non-voter can never lead
        self.role = CANDIDATE
        self.current_term += 1
        self.voted_for = self.nid
        self._abort_reads()
        self._persist_meta()
        self.votes = {self.nid}
        self._reset_election_deadline()
        for p in sorted(self.voters - {self.nid}):
            self._send(p, RequestVote(
                self.current_term, self.nid, self.last_log_index,
                self.term_at(self.last_log_index), transfer=transfer))
        if self._vote_quorum():
            self._become_leader()

    def _become_leader(self):
        self.role = LEADER
        self.leader_id = self.nid
        self.leadership_history.append((self.current_term, self.nid))
        self.next_index = {p: self.last_log_index + 1 for p in self.peers}
        self.match_index = {p: 0 for p in self.peers}
        self.match_index[self.nid] = self.last_log_index
        # fresh term: no lease, no probe acks, no transfer carry over
        self.lease_until = _NEVER
        self._probe_acked = {}
        self._ack_basis = {}
        self.peer_applied = {}
        self._transfer_until = _NEVER
        # no-op barrier entry to commit previous-term entries (Raft §8);
        # its index is also the floor for every ReadIndex in this term
        entry = LogEntry(self.current_term, self.last_log_index + 1,
                         KIND_NOOP, b"", b"")
        self._term_start_index = entry.index
        off = self.store.append(entry)
        self.store.commit_window()
        if _trace._ACTIVE is not None:
            _trace._ACTIVE.event("durable", self.addr, entry.index)
        self.entries.append(entry)
        self.offsets.append(off)
        self.match_index[self.nid] = self.last_log_index
        self._advance_commit()   # single-voter configs self-commit here
        self._broadcast_append()
        self._next_heartbeat = self.net.time + self.heartbeat_every

    # --------------------------------------------------------- replication
    def _broadcast_append(self):
        """One full round = one probe: each broadcast opens a fresh probe
        id whose echoes confirm leadership (ReadIndex) and renew the lease
        from the round's send time."""
        self._probe_seq += 1
        self._probe_sent[self._probe_seq] = self.net.time
        if len(self._probe_sent) > 128:   # bounded: old rounds are dead
            for k in sorted(self._probe_sent)[:-64]:
                del self._probe_sent[k]
        for p in self.peers:
            self._send_append(p)

    def send_snapshot_to(self, peer: int) -> bool:
        """Ship the engine's snapshot (whole run set) to one peer — used
        for log catch-up and as run shipping's fence-mismatch fallback."""
        if self.snapshot_fn is None:
            return False
        snap = self.snapshot_fn()
        if snap is None:
            return False
        li, lt, payload = snap
        ci, cv, cl = self._config_at(li)
        t = _trace._ACTIVE
        self._send(peer, InstallSnapshot(
            self.current_term, self.nid, li, lt, payload,
            config_index=ci, voters=cv, learners=cl,
            ctx=t.current() if t is not None else 0))
        if self.shipper is not None:
            # the snapshot carries the whole current run set: skip the
            # peer's shipping cursor past every record it supersedes,
            # once the matching install ack comes back
            self.shipper.on_snapshot_sent(peer, li)
        return True

    def _send_append(self, peer: int):
        ni = self.next_index.get(peer, self.last_log_index + 1)
        if ni <= self.snap_index:
            # follower is behind our snapshot -> ship it
            if self.send_snapshot_to(peer):
                return
            ni = self.snap_index + 1  # fallback (shouldn't happen)
        prev = ni - 1
        ents = [self._hydrated(i) for i in
                range(ni, min(self.last_log_index,
                              ni + self.max_batch - 1) + 1)]
        size = sum(len(e.key) + len(e.value) + 19 for e in ents)
        t = _trace._ACTIVE
        ctx = t.ctx_for_range(ents[0].index, ents[-1].index,
                              group=self.group) \
            if (t is not None and ents) else 0
        self._send(peer, AppendEntries(
            self.current_term, self.nid, prev, self.term_at(prev), ents,
            self.commit_index, probe=self._probe_seq, ctx=ctx), size=size)

    def _handle(self, src: int, msg):
        if isinstance(msg, RequestVote):
            self._on_request_vote(src, msg)
        elif isinstance(msg, RequestVoteReply):
            self._on_vote_reply(src, msg)
        elif isinstance(msg, AppendEntries):
            self._on_append(src, msg)
        elif isinstance(msg, AppendEntriesReply):
            self._on_append_reply(src, msg)
        elif isinstance(msg, InstallSnapshot):
            self._on_install_snapshot(src, msg)
        elif isinstance(msg, InstallSnapshotReply):
            self._on_snapshot_reply(src, msg)
        elif isinstance(msg, TimeoutNow):
            self._on_timeout_now(src, msg)
        elif isinstance(msg, ShipRun):
            if self.adopter is not None:
                self.adopter.on_chunk(src, msg)
        elif isinstance(msg, ShipRunReply):
            if self.shipper is not None:
                self.shipper.on_reply(src, msg)

    def _note_leader_contact(self):
        """Valid leader traffic: reset the election timer AND remember the
        contact time for vote stickiness."""
        self._last_leader_contact = self.net.time
        self._reset_election_deadline()

    def _on_request_vote(self, src: int, m: RequestVote):
        if m.candidate not in self.voters:
            # Thesis §4.2.3: per our config this server cannot lead.  A
            # removed node's runaway term must not disturb the live
            # quorum, so we do not even adopt its term — total silence.
            return
        if not m.transfer and \
                self.net.time - self._last_leader_contact < self.eto[0]:
            # Leader stickiness (Raft §9.6 / thesis §4.2.3): we heard from
            # a live leader within the minimum election timeout, so we
            # disregard the request ENTIRELY — no term adoption, no vote.
            # Without this, a follower whose probe acks are renewing the
            # leader's lease could simultaneously vote a new leader in,
            # and a LEASE read on the old leader would serve stale data
            # inside its supposedly-safe window.
            return
        if m.term > self.current_term:
            self._become_follower(m.term)
        granted = False
        if m.term == self.current_term and self.voted_for in (None, m.candidate):
            my_last_term = self.term_at(self.last_log_index)
            up_to_date = (m.last_log_term, m.last_log_index) >= \
                (my_last_term, self.last_log_index)
            if up_to_date:
                granted = True
                self.voted_for = m.candidate
                self._persist_meta()
                self._reset_election_deadline()
        self._send(src, RequestVoteReply(self.current_term, granted))

    def _on_vote_reply(self, src: int, m: RequestVoteReply):
        if m.term > self.current_term:
            self._become_follower(m.term)
            return
        if self.role != CANDIDATE or m.term != self.current_term:
            return
        if m.granted:
            self.votes.add(src)
            if self._vote_quorum():   # only votes from voters count
                self._become_leader()

    def _on_append(self, src: int, m: AppendEntries):
        if m.term > self.current_term:
            self._become_follower(m.term)
        if m.term < self.current_term:
            self._send(src, AppendEntriesReply(
                self.current_term, False, 0))
            return
        if self.role == LEADER:
            # a second leader in our own term is impossible; reaching here
            # means m.term == current_term while we lead — never true, but
            # stepping down must abort reads if it ever becomes reachable
            self._abort_reads()
        self.role = FOLLOWER
        self.leader_id = m.leader
        self._note_leader_contact()
        # log consistency check — still echoes the probe: even a failed
        # consistency check acknowledges the sender's leadership
        if m.prev_log_index > self.last_log_index or \
                self.term_at(m.prev_log_index) != m.prev_log_term:
            self._send(src, AppendEntriesReply(
                self.current_term, False, self.snap_index, probe=m.probe,
                applied=self.last_applied))
            return
        # skip the prefix we already hold (snapshot-covered or term-matching)
        start = 0
        while start < len(m.entries):
            idx = m.prev_log_index + 1 + start
            if idx <= self.snap_index or \
                    (idx <= self.last_log_index and
                     self.term_at(idx) == m.entries[start].term):
                start += 1
            else:
                break
        t = _trace._ACTIVE
        if start < len(m.entries):
            idx = m.prev_log_index + 1 + start
            # graft this follower's durability work onto the originating
            # op's span (m.ctx crossed the wire); ctx 0 (no originating
            # client op — e.g. a no-op barrier) makes it a root span
            sid = t.begin("follower.append", kind="raft", node=self.addr,
                          parent=m.ctx, n=len(m.entries) - start,
                          first=idx) if t is not None else None
            if idx <= self.last_log_index:
                # conflict: truncate our log from idx, once
                keep = idx - self.snap_index - 1
                if keep < len(self.offsets):
                    self.store.truncate_from(idx)
                self.entries = self.entries[:keep]
                self.offsets = self.offsets[:keep]
                self._rollback_configs(idx)
            batch = m.entries[start:]
            offs = self.store.append_batch(batch)  # single persistence pass
            self.entries.extend(batch)
            self.offsets.extend(offs)
            self.store.commit_window()             # durable before the ack
            if t is not None:
                t.event("durable", self.addr, batch[-1].index)
                t.end(sid)
            for e in batch:
                if e.kind == KIND_CONFIG:          # effective on append
                    self._adopt_config_entry(e)
        idx = m.prev_log_index + len(m.entries)
        if m.leader_commit > self.commit_index:
            self.commit_index = min(m.leader_commit, self.last_log_index)
            if t is not None:
                t.event("commit_learned", self.addr, self.commit_index,
                        leader=self._addr(m.leader))
        self._apply_committed()
        if t is not None:
            t.event("ack_sent", self.addr, idx, to=self._addr(src))
        self._send(src, AppendEntriesReply(
            self.current_term, True, idx, probe=m.probe,
            applied=self.last_applied, ctx=m.ctx))

    def _on_append_reply(self, src: int, m: AppendEntriesReply):
        if m.term > self.current_term:
            self._become_follower(m.term)
            return
        if self.role != LEADER or m.term != self.current_term:
            return
        # probe echo: leadership acknowledged as of the round's send time
        # (success or not), driving ReadIndex confirmation + lease renewal
        if m.applied > self.peer_applied.get(src, _NEVER):
            self.peer_applied[src] = m.applied   # learner promotion gauge
        if m.probe and m.probe > self._probe_acked.get(src, 0):
            self._probe_acked[src] = m.probe
            basis = self._probe_sent.get(m.probe)
            if basis is not None and \
                    basis > self._ack_basis.get(src, _NEVER):
                self._ack_basis[src] = basis
                self._refresh_lease()
            self._check_read_quorum()
        if m.success:
            if _trace._ACTIVE is not None:
                _trace._ACTIVE.event("ack_recv", self.addr, m.match_index,
                                     **{"from": self._addr(src)})
            self.match_index[src] = max(self.match_index.get(src, 0),
                                        m.match_index)
            self.next_index[src] = self.match_index[src] + 1
            self._advance_commit()
            if self.next_index[src] <= self.last_log_index:
                self._send_append(src)
        else:
            self.next_index[src] = max(
                1, min(self.next_index.get(src, 1) - self.max_batch,
                       m.match_index + 1))
            self._send_append(src)

    def _advance_commit(self):
        for n in range(self.last_log_index, self.commit_index, -1):
            if self.term_at(n) != self.current_term:
                break
            # quorum over the CURRENT voter set — a config entry commits
            # under itself (effective on append); learners never count
            votes = sum(1 for v in self.voters
                        if self.match_index.get(v, 0) >= n)
            if self._quorum(votes):
                self.commit_index = n
                if _trace._ACTIVE is not None:
                    _trace._ACTIVE.event("commit", self.addr, n,
                                         voters=[self._addr(v) for v
                                                 in sorted(self.voters)])
                break
        if self.role == LEADER and self.nid not in self.voters and \
                self.config_index <= self.commit_index:
            # we led the removal of ourselves and it just committed
            self._step_down()
        self._apply_committed()

    def _apply_committed(self):
        before = self.last_applied
        batch: List[Tuple[LogEntry, int]] = []
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            if self.last_applied <= self.snap_index:
                continue
            e = self.entry_at(self.last_applied)
            off = self.offsets[self.last_applied - self.snap_index - 1]
            if e.kind == KIND_PUT:
                batch.append((e, off))
            self.applied_log.append((self.last_applied, e))
        t = _trace._ACTIVE
        if batch:
            sid = None
            if t is not None:
                # graft the apply under the newest originating op in the
                # drain (cross-node: the registry is tracer-global)
                sid = t.begin("apply", kind="apply", node=self.addr,
                              parent=t.ctx_for_range(
                                  batch[0][0].index,
                                  batch[-1][0].index,
                                  group=self.group),
                              n=len(batch))
            # whole drain applied as one group: engines coalesce the index
            # WAL records into one buffered write...
            if self.apply_batch_fn is not None:
                self.apply_batch_fn(batch)
            else:
                for e, off in batch:
                    self.apply_fn(e, off)
            # ...and ONE fsync for the window, not one per entry
            self.store.commit_window()
            if sid is not None:
                t.end(sid)
        if t is not None and self.last_applied > before:
            t.event("apply", self.addr, self.last_applied)

    # ----------------------------------------------------------- snapshot
    def repoint_offsets(self, new_offsets: Optional[Dict[int, int]]):
        """The engine rewrote part of its log store (tail rotation on run
        adoption / snapshot install): update the in-memory log's offsets
        for every surviving index it re-homed."""
        for i, off in (new_offsets or {}).items():
            p = i - self.snap_index - 1
            if 0 <= p < len(self.offsets):
                self.offsets[p] = off

    def compact_to(self, index: int, term: int):
        """Drop in-memory log prefix covered by an engine snapshot."""
        if index <= self.snap_index:
            return
        keep = index - self.snap_index
        self.entries = self.entries[keep:]
        self.offsets = self.offsets[keep:]
        self.snap_index = index
        self.snap_term = term
        # collapse config history the snapshot now covers into one base,
        # and pin it in the meta: the log entries that carried it are
        # gone, so recovery can no longer replay it from the log
        base = self._config_at(index)
        self._configs = [base] + [c for c in self._configs if c[0] > index]
        self._persist_meta()

    def _on_install_snapshot(self, src: int, m: InstallSnapshot):
        if m.term > self.current_term:
            self._become_follower(m.term)
        if m.term < self.current_term:
            return
        self.role = FOLLOWER
        self.leader_id = m.leader
        self._note_leader_contact()
        if m.last_index <= self.snap_index:
            # already at (or past) this state: ack it anyway so the leader
            # advances, and clear any adoption stuck waiting for a resync
            if self.adopter is not None:
                self.adopter.reset()
            self._send(src, InstallSnapshotReply(
                self.current_term, self.snap_index, ctx=m.ctx))
            return
        # Raft §7: when our log already holds the snapshot's last entry,
        # retain the suffix past it — a resync snapshot may lag entries we
        # have applied, and dropping them would regress the state machine
        keep_suffix = (m.last_index <= self.last_log_index and
                       self.term_at(m.last_index) == m.last_term)
        t = _trace._ACTIVE
        sid = t.begin("install_snapshot", kind="raft", node=self.addr,
                      parent=m.ctx, last_index=m.last_index,
                      keep_suffix=keep_suffix) if t is not None else None
        new_offsets = None
        if self.install_snapshot_fn is not None:
            new_offsets = self.install_snapshot_fn(m.last_index, m.last_term,
                                                   m.payload,
                                                   keep_tail=keep_suffix)
        if t is not None:
            t.event("snapshot_install", self.addr, m.last_index,
                    leader=self._addr(src))
            t.end(sid)
        if self.adopter is not None:
            self.adopter.reset()   # the snapshot supersedes in-flight ships
        if keep_suffix:
            drop = m.last_index - self.snap_index
            self.entries = self.entries[drop:]
            self.offsets = self.offsets[drop:]
        else:
            self.entries = []
            self.offsets = []
        self.snap_index = m.last_index
        self.snap_term = m.last_term
        # the engine rewrote the retained tail into a fresh segment:
        # re-point the surviving log at the new offsets
        self.repoint_offsets(new_offsets)
        if m.voters:
            # the snapshot's config becomes our base; configs from a
            # retained suffix stay stacked on top of it
            tail = [c for c in self._configs if c[0] > m.last_index] \
                if keep_suffix else []
            self._configs = [(m.config_index, tuple(m.voters),
                              tuple(m.learners))] + tail
            self._apply_config_change()
        self.commit_index = max(self.commit_index, m.last_index)
        self.last_applied = max(self.last_applied, m.last_index)
        self._send(src, InstallSnapshotReply(
            self.current_term, m.last_index, ctx=m.ctx))

    def _on_snapshot_reply(self, src: int, m: InstallSnapshotReply):
        if self.role != LEADER:
            return
        if _trace._ACTIVE is not None:
            # an installed snapshot is durable applied state: it counts
            # as this peer's ack for everything through match_index
            _trace._ACTIVE.event("ack_recv", self.addr, m.match_index,
                                 **{"from": self._addr(src)})
        self.match_index[src] = max(self.match_index.get(src, 0),
                                    m.match_index)
        self.next_index[src] = self.match_index[src] + 1
        if m.match_index > self.peer_applied.get(src, _NEVER):
            # an installed snapshot IS applied state through its index
            self.peer_applied[src] = m.match_index
        if self.shipper is not None:
            self.shipper.on_snapshot_acked(src, m.match_index)
