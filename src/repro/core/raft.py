"""Raft consensus (arXiv 1409.585 / Ongaro & Ousterhout 2014), deterministic
and storage-pluggable.

The persistence hook is the point of the paper: ``log_store.append(entry)``
is invoked exactly once per log entry, BEFORE the entry is acknowledged, and
returns the byte offset of the persisted record.  In KVS-Raft the log store
is the ValueLog itself, so that single append persists the value, and the
state machine receives (entry, offset) at apply time — storing only the
offset (paper Algorithm 1).

Safety-property surface tested by tests/test_raft_properties.py:
  Election Safety, Log Matching, Leader Completeness, State Machine Safety.

Read path (client.py's consistency tiers ride on these primitives):

  * ReadIndex (§6.4): `read_index_submit()` records the commit index and
    queues a ReadHandle; the next tick starts ONE heartbeat-quorum round
    (a `probe` sequence number piggybacked on AppendEntries and echoed in
    the reply) that confirms leadership for EVERY read queued at that
    moment.  A handle turns `ready` once confirmed and applied >= its
    read index; losing leadership turns it `aborted` instead — a deposed
    leader can never serve a possibly-stale linearizable read.
  * Leader lease: every probe ack also carries evidence the follower
    still accepted us as leader at the probe's SEND time; when a majority
    (incl. self) acked probes sent at time t, the lease extends to
    t + lease_ticks.  `lease_valid()` then authorizes local reads with no
    quorum round.  Soundness rests on two legs: lease_ticks <
    min(election_timeout), and leader stickiness — a node disregards
    RequestVote within min(election_timeout) of valid leader traffic
    (§9.6), so the followers renewing a lease can never simultaneously
    form the majority that elects the leader's replacement.

Durability contract (see engines.py for the full statement): this module
itself performs no file I/O — everything durable flows through the log
store.  The two commitments Raft relies on are (a) `commit_window()` is
called before any ack/commit ("durable before ack" below), so an acked
entry is on disk at every crash point the FaultFS sweep can inject, and
(b) `persist_meta()` lands term/vote atomically, so kill -9 can never
resurrect a pre-vote term and double-grant a vote.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.simnet import SimNet
from repro.core.valuelog import KIND_NOOP, KIND_PUT, LogEntry

FOLLOWER, CANDIDATE, LEADER = "follower", "candidate", "leader"

_NEVER = -(10 ** 9)


# ------------------------------------------------------------------ messages
@dataclass
class RequestVote:
    term: int
    candidate: int
    last_log_index: int
    last_log_term: int


@dataclass
class RequestVoteReply:
    term: int
    granted: bool


@dataclass
class AppendEntries:
    term: int
    leader: int
    prev_log_index: int
    prev_log_term: int
    entries: List[LogEntry]
    leader_commit: int
    # ReadIndex/lease piggyback: the leader's heartbeat-quorum round id.
    # A follower echoes it in its reply; any reply (success or not) in the
    # leader's term proves the follower still accepted its leadership when
    # this round was SENT — which is exactly what ReadIndex confirmation
    # and lease renewal need.  0 = no round attached (legacy traffic).
    probe: int = 0


@dataclass
class AppendEntriesReply:
    term: int
    success: bool
    match_index: int
    probe: int = 0    # echo of AppendEntries.probe


@dataclass
class ReadHandle:
    """One pending consistency-tiered read on the leader (client.py).

    Lifecycle: submitted (probe=None) -> assigned to the next quorum round
    (probe=round id) -> `confirmed` when a majority echoed that round ->
    `ready` once last_applied >= read_index.  `aborted` is terminal: the
    node lost leadership (or the client timed it out) before confirmation,
    so serving would risk a stale read."""
    read_index: int
    probe: Optional[int] = None
    confirmed: bool = False
    ready: bool = False
    aborted: bool = False


@dataclass
class InstallSnapshot:
    term: int
    leader: int
    last_index: int
    last_term: int
    payload: Any  # engine-defined snapshot blob (e.g. sorted ValueLog bytes)


@dataclass
class InstallSnapshotReply:
    term: int
    match_index: int


@dataclass
class ShipRun:
    """One chunk of a run-adoption record (leader-driven GC replication).

    `rec` is the adoption record metadata built by the engine when it seals
    a run: kind ('flush'|'merge'), level, (last_index, last_term) run
    boundary, boundary_before/boundary store boundaries, retire identities,
    pos=(leader term, ship epoch), size and nchunks.  Chunks are resumable:
    the follower acks its contiguous prefix and the leader retransmits from
    there, so crashes/partitions/drops mid-ship never lose the record."""
    term: int
    leader: int
    rec: dict
    seq: int          # chunk number, 0-based
    data: bytes


@dataclass
class ShipRunReply:
    term: int
    pos: Tuple[int, int]      # record this reply refers to
    have: int                 # contiguous chunks buffered for that record
    adopted: Tuple[int, int]  # follower's durable ship position
    resync: bool = False      # fence tripped: please InstallSnapshot me


class LogStoreBase:
    """Persistence interface the engines implement."""

    def append(self, entry: LogEntry) -> int:
        raise NotImplementedError

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        """Group-commit hook: persist a whole batch with one buffered write.
        Engines override to coalesce; the default just loops."""
        return [self.append(e) for e in entries]

    def commit_window(self):
        """Group-commit boundary: make everything appended/applied since the
        last call durable with at most one fsync per underlying file.
        Called by Raft BEFORE acknowledging a batch.  Default: no-op."""

    def truncate_from(self, index: int):
        raise NotImplementedError

    def persist_meta(self, term: int, voted_for: Optional[int]):
        pass


class RaftNode:
    def __init__(self, nid: int, peers: List[int], net: SimNet,
                 log_store: LogStoreBase,
                 apply_fn: Callable[[LogEntry, int], None],
                 apply_batch_fn: Optional[
                     Callable[[List[Tuple[LogEntry, int]]], None]] = None,
                 *, seed: int = 0,
                 election_timeout: Tuple[int, int] = (20, 40),
                 heartbeat_every: int = 5,
                 max_entries_per_rpc: int = 64,
                 max_batch: Optional[int] = None,
                 lease_ticks: Optional[int] = None,
                 snapshot_fn: Optional[Callable[[], Optional[Tuple[int, int, Any]]]] = None,
                 install_snapshot_fn: Optional[Callable[[int, int, Any], None]] = None):
        self.nid = nid
        self.peers = [p for p in peers if p != nid]
        self.net = net
        self.store = log_store
        self.apply_fn = apply_fn
        self.apply_batch_fn = apply_batch_fn
        self.snapshot_fn = snapshot_fn
        self.install_snapshot_fn = install_snapshot_fn
        self.rng = random.Random(seed * 7919 + nid)
        self.eto = election_timeout
        self.heartbeat_every = heartbeat_every
        # max_batch governs BOTH entries-per-AppendEntries and the
        # group-commit window (one fsync per window, see client_put_many);
        # max_entries_per_rpc is its default when unset
        self.max_batch = max_batch if max_batch is not None \
            else max_entries_per_rpc
        # leader lease duration; must stay under min(election_timeout) —
        # vote stickiness only shields that long, so a bigger lease would
        # let a rival leader be elected while the old lease reads valid
        self.lease_ticks = lease_ticks if lease_ticks is not None \
            else max(1, election_timeout[0] - heartbeat_every)
        if self.lease_ticks >= election_timeout[0]:
            # correctness invariant, not a debug check (asserts vanish
            # under python -O): an oversized lease outlives the vote-
            # stickiness window and re-opens the stale-lease-read hole
            raise ValueError(
                f"lease_ticks={self.lease_ticks} must stay under the "
                f"minimum election timeout {election_timeout[0]} "
                "(lease safety)")

        self.current_term = 0
        self.voted_for: Optional[int] = None
        # in-memory log: entries[i] covers raft index snap_index + 1 + i
        self.entries: List[LogEntry] = []
        self.offsets: List[int] = []
        self.snap_index = 0
        self.snap_term = 0

        self.role = FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.leader_id: Optional[int] = None
        self.next_index: Dict[int, int] = {}
        self.match_index: Dict[int, int] = {}
        self.votes: set = set()
        # run-shipping endpoints (wired by the cluster when the engine has
        # run_shipping enabled): the leader's RunShipper streams sealed-run
        # chunks, the follower's RunAdopter assembles + installs them
        self.shipper = None
        self.adopter = None
        # ReadIndex / lease state (leader-only; see module docstring).
        # _probe_sent maps round id -> send time; _probe_acked / _ack_basis
        # track, per peer, the newest round echoed and the send time of
        # that round (the lease basis).  metrics is wired by the cluster
        # so quorum rounds triggered by reads are byte-counter evidence.
        self.pending_reads: List[ReadHandle] = []
        self.lease_until = _NEVER
        self.metrics = None
        # last time valid leader traffic arrived (AppendEntries /
        # InstallSnapshot / ShipRun in a current term) — the basis for
        # leader stickiness in _on_request_vote, which is what makes the
        # lease sound: no majority can form inside a live leader's lease
        self._last_leader_contact = _NEVER
        self._probe_seq = 0
        self._probe_sent: Dict[int, int] = {}
        self._probe_acked: Dict[int, int] = {}
        self._ack_basis: Dict[int, int] = {}
        self._term_start_index = 0
        self._reset_election_deadline()
        self._next_heartbeat = 0
        # metrics for tests
        self.applied_log: List[Tuple[int, LogEntry]] = []
        self.leadership_history: List[Tuple[int, int]] = []

    # ------------------------------------------------------------- helpers
    def _reset_election_deadline(self):
        self.election_deadline = self.net.time + self.rng.randint(*self.eto)

    @property
    def last_log_index(self) -> int:
        return self.snap_index + len(self.entries)

    def term_at(self, index: int) -> int:
        if index == self.snap_index:
            return self.snap_term
        if index < self.snap_index or index > self.last_log_index:
            return -1
        return self.entries[index - self.snap_index - 1].term

    def entry_at(self, index: int) -> LogEntry:
        return self.entries[index - self.snap_index - 1]

    def _hydrated(self, index: int) -> LogEntry:
        """Lazy-value recovery support: entries restored via header-only
        scans carry value=b'' and are re-read from the log store before
        being replicated to a follower."""
        e = self.entry_at(index)
        if getattr(e, "value_len", 0) and not e.value and \
                hasattr(self.store, "load_full_entry"):
            off = self.offsets[index - self.snap_index - 1]
            full = self.store.load_full_entry(index, off)
            self.entries[index - self.snap_index - 1] = full
            return full
        return e

    def _persist_meta(self):
        self.store.persist_meta(self.current_term, self.voted_for)

    def _become_follower(self, term: int):
        self.current_term = term
        self.role = FOLLOWER
        self.voted_for = None
        self.votes = set()
        self._abort_reads()   # a deposed leader must refuse pending reads
        self._persist_meta()
        # NOTE: no election-deadline reset here.  The timer resets only on
        # granting a vote or on valid leader traffic (AppendEntries /
        # InstallSnapshot / ShipRun); a bare term bump must not — otherwise
        # a disruptive candidate with a stale log and a short timeout can
        # reset everyone forever and no electable node ever stands.

    # ------------------------------------------------------- read tiers
    def _abort_reads(self):
        """Leadership is gone (or never confirmed): every queued read is
        refused rather than risk serving stale state, and the lease dies."""
        for h in self.pending_reads:
            h.aborted = True
        self.pending_reads = []
        self.lease_until = _NEVER
        self._probe_acked = {}
        self._ack_basis = {}

    def read_index_submit(self) -> Optional[ReadHandle]:
        """LINEARIZABLE tier: queue a ReadIndex read.  The read index is
        the current commit index, floored at this term's no-op barrier —
        before the barrier commits the leader cannot know its commit index
        is up to date (Raft §8 / §6.4).  One heartbeat-quorum round on the
        next tick confirms leadership for the whole queue."""
        if self.role != LEADER:
            return None
        return_index = max(self.commit_index, self._term_start_index)
        h = ReadHandle(read_index=return_index)
        self.pending_reads.append(h)
        return h

    def lease_valid(self) -> bool:
        """LEASE tier: may this node serve a local read with no quorum
        round right now?  Requires leadership, the term barrier committed
        (same reason as ReadIndex), and — with peers — a lease renewed by
        a recent heartbeat-quorum ack basis."""
        if self.role != LEADER or self.commit_index < self._term_start_index:
            return False
        return not self.peers or self.net.time < self.lease_until

    def _refresh_lease(self):
        """Lease = (send time of the newest probe a MAJORITY has acked,
        self included) + lease_ticks.  Sort peer ack bases descending and
        take the quorum-th: every node in that set accepted our leadership
        no earlier than that instant."""
        if not self.peers:
            return
        bases = sorted((self._ack_basis.get(p, _NEVER) for p in self.peers),
                       reverse=True)
        need = (len(self.peers) + 1) // 2   # peers needed beyond self
        basis = bases[need - 1]
        if basis > _NEVER:
            self.lease_until = max(self.lease_until,
                                   basis + self.lease_ticks)

    def _dispatch_read_round(self):
        """Assign every not-yet-assigned pending read to ONE fresh
        heartbeat round — the batching that makes ReadIndex cheap: a
        queue of N reads costs one quorum round, not N."""
        if not any(h.probe is None for h in self.pending_reads):
            return False
        self._broadcast_append()
        for h in self.pending_reads:
            if h.probe is None:
                h.probe = self._probe_seq
        if self.metrics is not None:
            self.metrics.on_read_quorum_round()
        self._check_read_quorum()   # single-node: quorum of 1, instantly
        return True

    def _check_read_quorum(self):
        for h in self.pending_reads:
            if h.probe is not None and not h.confirmed:
                acks = 1 + sum(1 for p in self.peers
                               if self._probe_acked.get(p, 0) >= h.probe)
                if acks * 2 > len(self.peers) + 1:
                    h.confirmed = True
        self._serve_ready_reads()

    def _serve_ready_reads(self):
        keep = []
        for h in self.pending_reads:
            if h.confirmed and self.last_applied >= h.read_index:
                h.ready = True
            elif not h.aborted:
                keep.append(h)
        self.pending_reads = keep

    # ------------------------------------------------------------ client
    def client_put(self, key: bytes, value: bytes) -> Optional[int]:
        """Leader-only. Appends + persists once; returns the raft index."""
        if self.role != LEADER:
            return None
        entry = LogEntry(self.current_term, self.last_log_index + 1,
                         KIND_PUT, key, value)
        off = self.store.append(entry)           # THE single persistence
        self.store.commit_window()               # durable before ack
        self.entries.append(entry)
        self.offsets.append(off)
        self.match_index[self.nid] = self.last_log_index
        if not self.peers:                       # single-node: self-commit
            self._advance_commit()
        return entry.index

    def client_put_many(self, items: List[Tuple[bytes, bytes]]
                        ) -> Optional[List[int]]:
        """Leader-only group commit: the whole batch is persisted with one
        buffered write + one fsync per store (append_batch/commit_window),
        then shipped to followers immediately in max_batch-sized
        AppendEntries instead of waiting for the next heartbeat."""
        if self.role != LEADER:
            return None
        entries = []
        base = self.last_log_index
        for i, (key, value) in enumerate(items):
            entries.append(LogEntry(self.current_term, base + 1 + i,
                                    KIND_PUT, key, value))
        offs = self.store.append_batch(entries)  # ONE persistence pass
        self.store.commit_window()               # ONE fsync per store
        self.entries.extend(entries)
        self.offsets.extend(offs)
        self.match_index[self.nid] = self.last_log_index
        if not self.peers:                       # single-node: self-commit
            self._advance_commit()
        # eager dispatch: a full window should not wait for the heartbeat
        self._broadcast_append()
        self._next_heartbeat = self.net.time + self.heartbeat_every
        return [e.index for e in entries]

    # -------------------------------------------------------------- tick
    def tick(self):
        if self.nid in self.net.down:
            return
        for src, msg in self.net.deliver(self.nid):
            self._handle(src, msg)
        now = self.net.time
        if self.role == LEADER:
            # a queued ReadIndex batch rides its own round immediately
            # (read latency should not wait for the heartbeat timer); the
            # round doubles as the heartbeat
            if self._dispatch_read_round():
                self._next_heartbeat = now + self.heartbeat_every
            elif now >= self._next_heartbeat:
                self._broadcast_append()
                self._next_heartbeat = now + self.heartbeat_every
            if self.shipper is not None:
                self.shipper.tick()
        elif now >= self.election_deadline:
            self._start_election()
        self._apply_committed()
        if self.role == LEADER:
            self._serve_ready_reads()
        if self.adopter is not None and self.role != LEADER:
            self.adopter.tick()   # install pending records once applied

    # ---------------------------------------------------------- election
    def _start_election(self):
        self.role = CANDIDATE
        self.current_term += 1
        self.voted_for = self.nid
        self._abort_reads()
        self._persist_meta()
        self.votes = {self.nid}
        self._reset_election_deadline()
        for p in self.peers:
            self.net.send(self.nid, p, RequestVote(
                self.current_term, self.nid, self.last_log_index,
                self.term_at(self.last_log_index)))
        if not self.peers:
            self._become_leader()

    def _become_leader(self):
        self.role = LEADER
        self.leader_id = self.nid
        self.leadership_history.append((self.current_term, self.nid))
        self.next_index = {p: self.last_log_index + 1 for p in self.peers}
        self.match_index = {p: 0 for p in self.peers}
        self.match_index[self.nid] = self.last_log_index
        # fresh term: no lease, no probe acks carry over
        self.lease_until = _NEVER
        self._probe_acked = {}
        self._ack_basis = {}
        # no-op barrier entry to commit previous-term entries (Raft §8);
        # its index is also the floor for every ReadIndex in this term
        entry = LogEntry(self.current_term, self.last_log_index + 1,
                         KIND_NOOP, b"", b"")
        self._term_start_index = entry.index
        off = self.store.append(entry)
        self.store.commit_window()
        self.entries.append(entry)
        self.offsets.append(off)
        self.match_index[self.nid] = self.last_log_index
        if not self.peers:                       # single-node: self-commit
            self._advance_commit()
        self._broadcast_append()
        self._next_heartbeat = self.net.time + self.heartbeat_every

    # --------------------------------------------------------- replication
    def _broadcast_append(self):
        """One full round = one probe: each broadcast opens a fresh probe
        id whose echoes confirm leadership (ReadIndex) and renew the lease
        from the round's send time."""
        self._probe_seq += 1
        self._probe_sent[self._probe_seq] = self.net.time
        if len(self._probe_sent) > 128:   # bounded: old rounds are dead
            for k in sorted(self._probe_sent)[:-64]:
                del self._probe_sent[k]
        for p in self.peers:
            self._send_append(p)

    def send_snapshot_to(self, peer: int) -> bool:
        """Ship the engine's snapshot (whole run set) to one peer — used
        for log catch-up and as run shipping's fence-mismatch fallback."""
        if self.snapshot_fn is None:
            return False
        snap = self.snapshot_fn()
        if snap is None:
            return False
        li, lt, payload = snap
        self.net.send(self.nid, peer, InstallSnapshot(
            self.current_term, self.nid, li, lt, payload))
        if self.shipper is not None:
            # the snapshot carries the whole current run set: skip the
            # peer's shipping cursor past every record it supersedes,
            # once the matching install ack comes back
            self.shipper.on_snapshot_sent(peer, li)
        return True

    def _send_append(self, peer: int):
        ni = self.next_index.get(peer, self.last_log_index + 1)
        if ni <= self.snap_index:
            # follower is behind our snapshot -> ship it
            if self.send_snapshot_to(peer):
                return
            ni = self.snap_index + 1  # fallback (shouldn't happen)
        prev = ni - 1
        ents = [self._hydrated(i) for i in
                range(ni, min(self.last_log_index,
                              ni + self.max_batch - 1) + 1)]
        size = sum(len(e.key) + len(e.value) + 19 for e in ents)
        self.net.send(self.nid, peer, AppendEntries(
            self.current_term, self.nid, prev, self.term_at(prev), ents,
            self.commit_index, probe=self._probe_seq), size=size)

    def _handle(self, src: int, msg):
        if isinstance(msg, RequestVote):
            self._on_request_vote(src, msg)
        elif isinstance(msg, RequestVoteReply):
            self._on_vote_reply(src, msg)
        elif isinstance(msg, AppendEntries):
            self._on_append(src, msg)
        elif isinstance(msg, AppendEntriesReply):
            self._on_append_reply(src, msg)
        elif isinstance(msg, InstallSnapshot):
            self._on_install_snapshot(src, msg)
        elif isinstance(msg, InstallSnapshotReply):
            self._on_snapshot_reply(src, msg)
        elif isinstance(msg, ShipRun):
            if self.adopter is not None:
                self.adopter.on_chunk(src, msg)
        elif isinstance(msg, ShipRunReply):
            if self.shipper is not None:
                self.shipper.on_reply(src, msg)

    def _note_leader_contact(self):
        """Valid leader traffic: reset the election timer AND remember the
        contact time for vote stickiness."""
        self._last_leader_contact = self.net.time
        self._reset_election_deadline()

    def _on_request_vote(self, src: int, m: RequestVote):
        if self.net.time - self._last_leader_contact < self.eto[0]:
            # Leader stickiness (Raft §9.6 / thesis §4.2.3): we heard from
            # a live leader within the minimum election timeout, so we
            # disregard the request ENTIRELY — no term adoption, no vote.
            # Without this, a follower whose probe acks are renewing the
            # leader's lease could simultaneously vote a new leader in,
            # and a LEASE read on the old leader would serve stale data
            # inside its supposedly-safe window.
            return
        if m.term > self.current_term:
            self._become_follower(m.term)
        granted = False
        if m.term == self.current_term and self.voted_for in (None, m.candidate):
            my_last_term = self.term_at(self.last_log_index)
            up_to_date = (m.last_log_term, m.last_log_index) >= \
                (my_last_term, self.last_log_index)
            if up_to_date:
                granted = True
                self.voted_for = m.candidate
                self._persist_meta()
                self._reset_election_deadline()
        self.net.send(self.nid, src, RequestVoteReply(self.current_term,
                                                      granted))

    def _on_vote_reply(self, src: int, m: RequestVoteReply):
        if m.term > self.current_term:
            self._become_follower(m.term)
            return
        if self.role != CANDIDATE or m.term != self.current_term:
            return
        if m.granted:
            self.votes.add(src)
            if len(self.votes) * 2 > len(self.peers) + 1:
                self._become_leader()

    def _on_append(self, src: int, m: AppendEntries):
        if m.term > self.current_term:
            self._become_follower(m.term)
        if m.term < self.current_term:
            self.net.send(self.nid, src, AppendEntriesReply(
                self.current_term, False, 0))
            return
        if self.role == LEADER:
            # a second leader in our own term is impossible; reaching here
            # means m.term == current_term while we lead — never true, but
            # stepping down must abort reads if it ever becomes reachable
            self._abort_reads()
        self.role = FOLLOWER
        self.leader_id = m.leader
        self._note_leader_contact()
        # log consistency check — still echoes the probe: even a failed
        # consistency check acknowledges the sender's leadership
        if m.prev_log_index > self.last_log_index or \
                self.term_at(m.prev_log_index) != m.prev_log_term:
            self.net.send(self.nid, src, AppendEntriesReply(
                self.current_term, False, self.snap_index, probe=m.probe))
            return
        # skip the prefix we already hold (snapshot-covered or term-matching)
        start = 0
        while start < len(m.entries):
            idx = m.prev_log_index + 1 + start
            if idx <= self.snap_index or \
                    (idx <= self.last_log_index and
                     self.term_at(idx) == m.entries[start].term):
                start += 1
            else:
                break
        if start < len(m.entries):
            idx = m.prev_log_index + 1 + start
            if idx <= self.last_log_index:
                # conflict: truncate our log from idx, once
                keep = idx - self.snap_index - 1
                if keep < len(self.offsets):
                    self.store.truncate_from(idx)
                self.entries = self.entries[:keep]
                self.offsets = self.offsets[:keep]
            batch = m.entries[start:]
            offs = self.store.append_batch(batch)  # single persistence pass
            self.entries.extend(batch)
            self.offsets.extend(offs)
            self.store.commit_window()             # durable before the ack
        idx = m.prev_log_index + len(m.entries)
        if m.leader_commit > self.commit_index:
            self.commit_index = min(m.leader_commit, self.last_log_index)
        self.net.send(self.nid, src, AppendEntriesReply(
            self.current_term, True, idx, probe=m.probe))
        self._apply_committed()

    def _on_append_reply(self, src: int, m: AppendEntriesReply):
        if m.term > self.current_term:
            self._become_follower(m.term)
            return
        if self.role != LEADER or m.term != self.current_term:
            return
        # probe echo: leadership acknowledged as of the round's send time
        # (success or not), driving ReadIndex confirmation + lease renewal
        if m.probe and m.probe > self._probe_acked.get(src, 0):
            self._probe_acked[src] = m.probe
            basis = self._probe_sent.get(m.probe)
            if basis is not None and \
                    basis > self._ack_basis.get(src, _NEVER):
                self._ack_basis[src] = basis
                self._refresh_lease()
            self._check_read_quorum()
        if m.success:
            self.match_index[src] = max(self.match_index.get(src, 0),
                                        m.match_index)
            self.next_index[src] = self.match_index[src] + 1
            self._advance_commit()
            if self.next_index[src] <= self.last_log_index:
                self._send_append(src)
        else:
            self.next_index[src] = max(
                1, min(self.next_index.get(src, 1) - self.max_batch,
                       m.match_index + 1))
            self._send_append(src)

    def _advance_commit(self):
        for n in range(self.last_log_index, self.commit_index, -1):
            if self.term_at(n) != self.current_term:
                break
            votes = sum(1 for p in self.match_index.values() if p >= n)
            if votes * 2 > len(self.peers) + 1:
                self.commit_index = n
                break
        self._apply_committed()

    def _apply_committed(self):
        batch: List[Tuple[LogEntry, int]] = []
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            if self.last_applied <= self.snap_index:
                continue
            e = self.entry_at(self.last_applied)
            off = self.offsets[self.last_applied - self.snap_index - 1]
            if e.kind == KIND_PUT:
                batch.append((e, off))
            self.applied_log.append((self.last_applied, e))
        if batch:
            # whole drain applied as one group: engines coalesce the index
            # WAL records into one buffered write...
            if self.apply_batch_fn is not None:
                self.apply_batch_fn(batch)
            else:
                for e, off in batch:
                    self.apply_fn(e, off)
            # ...and ONE fsync for the window, not one per entry
            self.store.commit_window()

    # ----------------------------------------------------------- snapshot
    def repoint_offsets(self, new_offsets: Optional[Dict[int, int]]):
        """The engine rewrote part of its log store (tail rotation on run
        adoption / snapshot install): update the in-memory log's offsets
        for every surviving index it re-homed."""
        for i, off in (new_offsets or {}).items():
            p = i - self.snap_index - 1
            if 0 <= p < len(self.offsets):
                self.offsets[p] = off

    def compact_to(self, index: int, term: int):
        """Drop in-memory log prefix covered by an engine snapshot."""
        if index <= self.snap_index:
            return
        keep = index - self.snap_index
        self.entries = self.entries[keep:]
        self.offsets = self.offsets[keep:]
        self.snap_index = index
        self.snap_term = term

    def _on_install_snapshot(self, src: int, m: InstallSnapshot):
        if m.term > self.current_term:
            self._become_follower(m.term)
        if m.term < self.current_term:
            return
        self.role = FOLLOWER
        self.leader_id = m.leader
        self._note_leader_contact()
        if m.last_index <= self.snap_index:
            # already at (or past) this state: ack it anyway so the leader
            # advances, and clear any adoption stuck waiting for a resync
            if self.adopter is not None:
                self.adopter.reset()
            self.net.send(self.nid, src, InstallSnapshotReply(
                self.current_term, self.snap_index))
            return
        # Raft §7: when our log already holds the snapshot's last entry,
        # retain the suffix past it — a resync snapshot may lag entries we
        # have applied, and dropping them would regress the state machine
        keep_suffix = (m.last_index <= self.last_log_index and
                       self.term_at(m.last_index) == m.last_term)
        new_offsets = None
        if self.install_snapshot_fn is not None:
            new_offsets = self.install_snapshot_fn(m.last_index, m.last_term,
                                                   m.payload,
                                                   keep_tail=keep_suffix)
        if self.adopter is not None:
            self.adopter.reset()   # the snapshot supersedes in-flight ships
        if keep_suffix:
            drop = m.last_index - self.snap_index
            self.entries = self.entries[drop:]
            self.offsets = self.offsets[drop:]
        else:
            self.entries = []
            self.offsets = []
        self.snap_index = m.last_index
        self.snap_term = m.last_term
        # the engine rewrote the retained tail into a fresh segment:
        # re-point the surviving log at the new offsets
        self.repoint_offsets(new_offsets)
        self.commit_index = max(self.commit_index, m.last_index)
        self.last_applied = max(self.last_applied, m.last_index)
        self.net.send(self.nid, src, InstallSnapshotReply(
            self.current_term, m.last_index))

    def _on_snapshot_reply(self, src: int, m: InstallSnapshotReply):
        if self.role != LEADER:
            return
        self.match_index[src] = max(self.match_index.get(src, 0),
                                    m.match_index)
        self.next_index[src] = self.match_index[src] + 1
        if self.shipper is not None:
            self.shipper.on_snapshot_acked(src, m.match_index)
