"""Multi-Raft sharded keyspace: N independent Raft groups over one SimNet.

Every put used to serialize through a single Raft leader — the wall
between this reproduction and "millions of users" (ROADMAP).  The paper's
key-value separation lowers per-op I/O but does nothing for single-leader
write serialization; following Bizur's observation that consensus
scalability comes from MANY SMALL consensus domains rather than a fatter
single log, this module partitions the keyspace into contiguous range
shards, each an independent Raft group with its own ``NezhaEngine``
(own workdir, own value log, own run shipping, own GC), all multiplexed
over ONE shared ``SimNet``.

Three layers:

* **ShardMap** — the routing table: sorted split keys defining
  ``len(splits)+1`` contiguous ranges.  ``shard_for(key)`` is a bisect;
  ``shards_for_range`` returns the contiguous group-id range a scan
  touches.  ``ShardMap.even`` interpolates splits uniformly over the
  keyspace's big-endian integer image.

* **ShardedCluster** — one ``Cluster`` per group, constructed with
  ``group=g`` and the shared net, so wire addresses are ``(group, nid)``
  tuples and each group keeps its own election timers, leases and
  membership (raft.py is group-oblivious: only its network boundary
  translates local ids to wire addresses).  Each group's ``tick`` is
  delegated back here (``_tick_parent``), so any group-local wait loop
  (elect, client retries, drain_shipping) advances net time ONCE and
  ticks EVERY group's nodes — the fabric never stalls because one shard
  is waiting.  Faults (kill_leader / partition / restart) target a
  specific group; the chaos scheduler drives them per-shard.

* **ShardedClient / ShardedSession** — routing client.  Point ops go to
  ``shard_for(key)``'s group client unchanged.  ``put_many`` splits the
  items into per-shard batches and drives one ``_ShardPipe`` per shard
  CONCURRENTLY: every pipe keeps its own in-flight window against its
  group's leader and all pipes share each ``tick`` (interleaved, not
  shard-serial), which is where the throughput scaling in
  benchmarks/fig_shard.py comes from — fsyncs and replication rounds of
  different shards overlap in virtual time.  Cross-shard scans
  scatter-gather shard-local scans (each with its tier's guarantees) and
  stitch them with the same ``kway_merge_newest_wins`` the LSM uses —
  shard ranges are disjoint, so the merge is a pure ordered
  concatenation and the result is byte-equal to an unsharded reference.
  A ``ShardedSession`` is a vector of per-group session tokens, so
  read-your-writes and monotonic reads hold across shard boundaries:
  a write on shard A advances A's token only, and a later read on shard
  B is governed by B's token — exactly the per-shard-vector design the
  HLC session-token ROADMAP item calls for.
"""
from __future__ import annotations

import os
import shutil
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core import trace as _trace
from repro.core.client import LINEARIZABLE, SESSION, Session
from repro.core.cluster import Cluster
from repro.core.metrics import Metrics
from repro.core.raft import LEADER, RaftNode
from repro.core.simnet import SimNet
from repro.core.storage import kway_merge_newest_wins


class ShardMap:
    """Range partitioning: ``splits`` are sorted keys; shard ``g`` owns
    ``[splits[g-1], splits[g])`` (open-ended at both extremes)."""

    def __init__(self, splits: List[bytes]):
        self.splits: List[bytes] = sorted(splits)
        self.n_shards = len(self.splits) + 1

    @classmethod
    def even(cls, n_shards: int, lo: bytes = b"",
             hi: bytes = b"\xff" * 8) -> "ShardMap":
        """Uniform splits over [lo, hi]: both bounds are padded to a
        common width and interpolated as big-endian integers, so keys
        with a shared prefix (e.g. ``user%010d``) still spread evenly
        as long as [lo, hi] brackets them."""
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if n_shards == 1:
            return cls([])
        width = max(len(lo), len(hi), 1)
        a = int.from_bytes(lo.ljust(width, b"\x00"), "big")
        b = int.from_bytes(hi.ljust(width, b"\xff"), "big")
        if b <= a:
            raise ValueError("key_hi must sort after key_lo")
        return cls([(a + (b - a) * i // n_shards).to_bytes(width, "big")
                    for i in range(1, n_shards)])

    @classmethod
    def from_keys(cls, keys: Iterable[bytes], n_shards: int) -> "ShardMap":
        """Quantile splits from a key sample.  ``even`` is uniform over
        the raw BYTE space, which skews badly for structured keys (e.g.
        decimal-string ids, where most of the byte space holds no key);
        sampling the actual distribution is how a production balancer
        picks splits, and what the benchmarks use."""
        ks = sorted(keys)
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if n_shards == 1 or not ks:
            return cls([])
        splits = sorted({ks[len(ks) * i // n_shards]
                         for i in range(1, n_shards)})
        return cls(splits)

    def shard_for(self, key: bytes) -> int:
        return bisect_right(self.splits, key)

    def shards_for_range(self, lo: bytes, hi: bytes) -> range:
        """Contiguous group ids a scan over [lo, hi] can touch.  Safe
        under either open or closed upper bounds: an extra boundary
        shard just contributes an empty part."""
        if hi < lo:
            return range(0)
        return range(self.shard_for(lo), self.shard_for(hi) + 1)

    def range_of(self, g: int) -> Tuple[Optional[bytes], Optional[bytes]]:
        """(inclusive lo, exclusive hi) of shard g; None = unbounded."""
        lo = self.splits[g - 1] if g > 0 else None
        hi = self.splits[g] if g < len(self.splits) else None
        return lo, hi


class ShardedSession:
    """Per-shard vector of session tokens.  Each group's Raft indexes are
    independent, so one scalar token is meaningless across shards; the
    vector gives exact read-your-writes + monotonic reads per shard,
    which composes to the cross-shard guarantee (any key's reads and
    writes always land on the same group)."""

    def __init__(self, client: "ShardedClient"):
        self.client = client
        self._per_group: Dict[int, Session] = {}

    def for_group(self, g: int) -> Session:
        s = self._per_group.get(g)
        if s is None:
            s = self.client.sc.groups[g].client.session()
            self._per_group[g] = s
        return s

    def vector(self) -> Dict[int, int]:
        """The token itself: group id -> last observed raft index."""
        return {g: s.last_index
                for g, s in sorted(self._per_group.items())}

    # ------------------------------------------------------------- sugar
    # Mirrors client.Session so workload/session-test call sites work on
    # either flavor unchanged.
    def observe(self, index) -> None:
        # A bare raft index is ambiguous across groups; the per-group
        # sessions already observe exact indexes on the write path.
        if isinstance(index, tuple):
            g, idx = index
            self.for_group(g).observe(idx)

    def put(self, key: bytes, value: bytes, **kw) -> int:
        g = self.client.sc.shard_map.shard_for(key)
        return self.for_group(g).put(key, value, **kw)

    def put_many(self, items, **kw) -> int:
        return self.client.put_many(items, session=self, **kw)

    def get(self, key: bytes, *, node: Optional[int] = None):
        g = self.client.sc.shard_map.shard_for(key)
        return self.for_group(g).get(key, node=node)

    def scan(self, lo: bytes, hi: bytes, *, node: Optional[int] = None):
        return self.client.scan(lo, hi, SESSION, session=self, node=node)


class _ShardPipe:
    """One shard's share of a cross-shard put_many: the same in-flight
    window state machine as NezhaClient._put_many_locked, but with the
    tick pulled OUT — the ShardedClient pumps every pipe, ticks the
    fabric once, then lets every pipe confirm, so all shards' windows
    are in flight simultaneously."""

    def __init__(self, cluster: Cluster, g: int, items: list, window: int,
                 batch: Optional[int], session: Optional[Session],
                 t, root: Optional[int]):
        self.c = cluster
        self.g = g
        self.it = iter(items)
        self.window = window
        self.batch = batch
        self.session = session
        self.t = t
        self.root = root
        self.sid: Optional[int] = None
        self.ld: Optional[RaftNode] = None
        self.inflight: List[Tuple[list, List[int]]] = []
        self.done = 0
        self.exhausted = False
        self.finished = False

    def _ensure_span(self):
        if self.t is None or self.sid is not None:
            return
        # one child span per shard under the put_many root; begin()
        # pushes it, exit() pops it — it is re-entered around each
        # submit so leader appends nest under the right shard subtree
        self.sid = self.t.begin("put_many.shard", kind="op",
                                shard=self.g, parent=self.root)
        self.t.exit(self.sid)

    def _submit(self, chunk) -> List[int]:
        self._ensure_span()
        if self.t is not None:
            self.t.enter(self.sid)
        try:
            idxs = self.ld.client_put_many(chunk)
            while idxs is None:           # deposed since elect(): re-elect
                self.ld = self.c.elect()
                idxs = self.ld.client_put_many(chunk)
            return idxs
        finally:
            if self.t is not None:
                self.t.exit(self.sid)

    def pump(self):
        """Refill this shard's window (submits only — no ticking)."""
        if self.finished:
            return
        if self.ld is None:
            self.ld = self.c.elect()
            if self.batch is None:
                self.batch = max(1, min(self.window, self.ld.max_batch))
        npending = sum(len(idxs) for _, idxs in self.inflight)
        while not self.exhausted and npending < self.window:
            chunk = []
            room = min(self.batch, self.window - npending)
            while len(chunk) < room:
                nxt = next(self.it, None)
                if nxt is None:
                    self.exhausted = True
                    break
                chunk.append(nxt)
            if not chunk:
                break
            self.inflight.append((chunk, self._submit(chunk)))
            npending += len(chunk)
        if self.exhausted and not self.inflight:
            self._finish()

    def confirm(self):
        """Count applied prefixes; resubmit everything on a leadership
        change (same at-least-once discipline as the unsharded path)."""
        if self.finished or self.ld is None:
            return
        if self.inflight:
            if self.ld.role != LEADER or self.c.leader() is not self.ld:
                self.ld = self.c.elect()
                self.inflight = [(chunk, self._submit(chunk))
                                 for chunk, _ in self.inflight]
            applied = self.ld.last_applied
            keep = []
            for chunk, idxs in self.inflight:
                ok = sum(1 for i in idxs if i <= applied)
                self.done += ok
                if self.t is not None and ok:
                    self.t.event("client_ack", self.ld.addr, idxs[ok - 1])
                if self.session is not None and ok:
                    self.session.observe(idxs[ok - 1])
                if ok < len(idxs):
                    keep.append((chunk[ok:], idxs[ok:]))
            self.inflight = keep
            for e in self.c.engines:
                if e is not None:
                    e.post_op()
        if self.exhausted and not self.inflight:
            self._finish()

    def _finish(self):
        self.finished = True
        if self.t is not None and self.sid is not None:
            self.t.end(self.sid)

    @property
    def pending(self) -> int:
        return sum(len(idxs) for _, idxs in self.inflight)


class ShardedClient:
    """ShardMap-aware routing client over per-group NezhaClients."""

    def __init__(self, sc: "ShardedCluster", *,
                 default_consistency: str = LINEARIZABLE):
        self.sc = sc
        self.default_consistency = default_consistency

    def session(self) -> ShardedSession:
        return ShardedSession(self)

    def _gs(self, session: Optional[ShardedSession],
            g: int) -> Optional[Session]:
        if session is None:
            return None
        if isinstance(session, Session):      # a bare per-group session
            return session
        return session.for_group(g)

    # ------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes, max_ticks: int = 2000) -> int:
        g = self.sc.shard_map.shard_for(key)
        return self.sc.groups[g].client.put(key, value,
                                            max_ticks=max_ticks)

    def put_many(self, items: Iterable[Tuple[bytes, bytes]],
                 window: int = 64, max_ticks: int = 200000,
                 batch: Optional[int] = None,
                 session: Optional[ShardedSession] = None) -> int:
        """Scatter the batch by shard and drive every shard's window in
        the SAME tick loop: each iteration pumps all pipes, advances the
        fabric one tick, then confirms all pipes.  N shards commit (and
        fsync, and replicate) concurrently in virtual time."""
        per: Dict[int, list] = {}
        for kv in items:
            per.setdefault(self.sc.shard_map.shard_for(kv[0]),
                           []).append(kv)
        if not per:
            return 0
        t = _trace._ACTIVE
        root = t.begin("put_many", kind="op", shards=len(per)) \
            if t is not None else None
        try:
            pipes = [_ShardPipe(self.sc.groups[g], g, part, window, batch,
                                self._gs(session, g), t, root)
                     for g, part in sorted(per.items())]
            for _ in range(max_ticks):
                active = [p for p in pipes if not p.finished]
                if not active:
                    return sum(p.done for p in pipes)
                for p in active:
                    p.pump()
                self.sc.tick()
                for p in active:
                    p.confirm()
            raise TimeoutError(
                "sharded put_many stalled: " + ", ".join(
                    f"shard{p.g}: {p.done} done, {p.pending} pending"
                    for p in pipes if not p.finished))
        finally:
            if root is not None:
                t.end(root)

    # -------------------------------------------------------------- reads
    def get(self, key: bytes, consistency: Optional[str] = None, *,
            session: Optional[ShardedSession] = None,
            node: Optional[int] = None) -> Optional[bytes]:
        g = self.sc.shard_map.shard_for(key)
        return self.sc.groups[g].client.get(
            key, consistency, session=self._gs(session, g), node=node)

    def scan(self, lo: bytes, hi: bytes,
             consistency: Optional[str] = None, *,
             session: Optional[ShardedSession] = None,
             node: Optional[int] = None):
        """Scatter-gather: shard-local scans (each under the requested
        tier's guarantees against its own group) stitched back together
        with the LSM's k-way merge.  Shard ranges are disjoint, so
        newest-wins dedup never fires and the stitched result is
        byte-equal to an unsharded reference scan."""
        gids = list(self.sc.shard_map.shards_for_range(lo, hi))
        if len(gids) == 1:
            g = gids[0]
            return self.sc.groups[g].client.scan(
                lo, hi, consistency, session=self._gs(session, g),
                node=node)
        t = _trace._ACTIVE
        sid = t.begin("scan.scatter", kind="op", shards=len(gids)) \
            if t is not None else None
        try:
            parts = [self.sc.groups[g].client.scan(
                lo, hi, consistency, session=self._gs(session, g),
                node=node) for g in gids]
            return list(kway_merge_newest_wins([iter(p) for p in parts]))
        finally:
            if sid is not None:
                t.end(sid)


class ShardedCluster:
    """N-shard fabric: one Cluster per range shard over a shared SimNet.

    The public surface mirrors Cluster (put/put_many/get/scan/session,
    tick/elect, registry/health_report, fault hooks) so benchmarks, the
    workload harness and the chaos scheduler drive either shape —
    fault hooks additionally take ``group=`` to target one shard."""

    def __init__(self, n_shards: int = 4, n: int = 3,
                 engine: str = "nezha", workdir: str = "", seed: int = 0,
                 shard_map: Optional[ShardMap] = None,
                 key_lo: bytes = b"", key_hi: bytes = b"\xff" * 8,
                 drop_prob: float = 0.0,
                 default_consistency: str = LINEARIZABLE,
                 **cluster_kwargs):
        self.shard_map = shard_map if shard_map is not None \
            else ShardMap.even(n_shards, key_lo, key_hi)
        self.n_shards = self.shard_map.n_shards
        self.n = n
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.net = SimNet([], seed=seed, drop_prob=drop_prob)
        self.groups: List[Cluster] = []
        for g in range(self.n_shards):
            c = Cluster(
                n=n, engine=engine,
                workdir=os.path.join(workdir, f"shard{g}"),
                # decorrelate per-group RNG streams (elections, drops)
                seed=seed + 1_000_003 * g,
                # stagger initial leaders across the replica slots so
                # one simulated host doesn't lead every shard
                leader_hint=g % n,
                default_consistency=default_consistency,
                group=g, net=self.net, **cluster_kwargs)
            c._tick_parent = self
            self.groups.append(c)
        self.client = ShardedClient(
            self, default_consistency=default_consistency)

    # ---------------------------------------------------------------- time
    def tick(self, k: int = 1):
        """Advance the fabric: net time moves ONCE per step and every
        group's nodes tick — this is what per-group Clusters delegate
        to, so shard-local wait loops keep the whole fabric live."""
        for _ in range(k):
            self.net.tick()
            for c in self.groups:
                for node in c.nodes:
                    if node is not None:
                        node.tick()

    def elect(self, max_ticks: int = 2000) -> List[RaftNode]:
        """Settle a leader in EVERY group; returns them by group id."""
        return [c.elect(max_ticks) for c in self.groups]

    def leader(self, group: int = 0) -> Optional[RaftNode]:
        return self.groups[group].leader()

    # -------------------------------------------------------------- client
    def put(self, key: bytes, value: bytes, max_ticks: int = 2000) -> int:
        return self.client.put(key, value, max_ticks=max_ticks)

    def put_many(self, items, window: int = 64, max_ticks: int = 200000,
                 batch: Optional[int] = None,
                 session: Optional[ShardedSession] = None):
        return self.client.put_many(items, window=window,
                                    max_ticks=max_ticks, batch=batch,
                                    session=session)

    def get(self, key: bytes, consistency: Optional[str] = None, *,
            session: Optional[ShardedSession] = None,
            node: Optional[int] = None) -> Optional[bytes]:
        return self.client.get(key, consistency, session=session,
                               node=node)

    def scan(self, lo: bytes, hi: bytes,
             consistency: Optional[str] = None, *,
             session: Optional[ShardedSession] = None,
             node: Optional[int] = None):
        return self.client.scan(lo, hi, consistency, session=session,
                                node=node)

    def session(self) -> ShardedSession:
        return self.client.session()

    # -------------------------------------------------------- aggregation
    @property
    def metrics(self) -> List[Metrics]:
        return [m for c in self.groups for m in c.metrics]

    @property
    def engines(self) -> List:
        return [e for c in self.groups for e in c.engines]

    def registry(self, reg: Optional["_trace.MetricsRegistry"] = None
                 ) -> "_trace.MetricsRegistry":
        """One merged registry: every per-group family gains a ``shard``
        label; shared-net counters are emitted exactly once (the groups
        don't own the net, so they skip them)."""
        reg = reg if reg is not None else _trace.MetricsRegistry()
        for g, c in enumerate(self.groups):
            c.registry(reg, shard=str(g))
        sent = reg.counter("repro_net_msgs_total",
                           "simnet messages by outcome", ["outcome"])
        sent.labels(outcome="sent").inc(self.net.sent_msgs)
        sent.labels(outcome="dropped").inc(self.net.dropped_msgs)
        drops = reg.counter("repro_net_drops_total",
                            "simnet drops by reason", ["reason"])
        for reason, cnt in sorted(self.net.drop_reasons.items()):
            drops.labels(reason=reason).inc(cnt)
        return reg

    def prometheus_text(self) -> str:
        return self.registry().prometheus_text()

    def scrape(self) -> dict:
        return self.registry().scrape()

    def health_report(self) -> dict:
        """Fabric-level summary: per-shard leader/term/role rollups plus
        the shared net's fault state and the merged registry scrape."""
        shards = []
        for g, c in enumerate(self.groups):
            ld = c.leader()
            lo, hi = self.shard_map.range_of(g)
            roles = {}
            for i, nd in enumerate(c.nodes):
                if nd is None:
                    roles[i] = "down"
                elif c.addr(i) in self.net.down:
                    roles[i] = "crashed"
                else:
                    roles[i] = nd.role
            shards.append({
                "shard": g,
                "range": [lo.hex() if lo is not None else None,
                          hi.hex() if hi is not None else None],
                "leader": ld.nid if ld is not None else None,
                "term": ld.current_term if ld is not None else None,
                "commit_index": ld.commit_index if ld is not None else None,
                "roles": roles,
            })
        return {
            "time": self.net.time,
            "n_shards": self.n_shards,
            "shards": shards,
            "net": {"sent_msgs": self.net.sent_msgs,
                    "dropped_msgs": self.net.dropped_msgs,
                    "drop_reasons": dict(self.net.drop_reasons),
                    "down": sorted(self.net.down),
                    "partitions": [sorted(p) for p in self.net.blocked]},
            "metrics": self.scrape(),
        }

    # --------------------------------------------------------------- trace
    def enable_tracing(self) -> "_trace.Tracer":
        t = _trace.Tracer(clock=lambda: self.net.time)
        _trace.install(t)
        for c in self.groups:
            for nd in c.nodes:
                if nd is not None:
                    c._baseline_events(nd)
        return t

    def disable_tracing(self) -> Optional["_trace.Tracer"]:
        t = _trace.active()
        _trace.uninstall()
        return t

    # --------------------------------------------------------------- faults
    # Same hook names as Cluster, plus group targeting: the chaos
    # scheduler resolves FaultEvent.group to one of these groups and
    # calls the group-cluster hooks directly (workload.py).
    def kill_leader(self, max_ticks: int = 2000, group: int = 0) -> int:
        return self.groups[group].kill_leader(max_ticks)

    def crash(self, i: int, group: int = 0):
        self.groups[group].crash(i)

    def restart(self, i: int, group: int = 0) -> float:
        return self.groups[group].restart(i)

    def partition(self, a: int, b: int, group: int = 0):
        self.groups[group].partition(a, b)

    def heal(self, a: int = None, b: int = None,
             group: Optional[int] = None):
        if group is None:
            self.net.heal()      # fabric-wide
        else:
            self.groups[group].heal(a, b)

    def isolate(self, i: int, group: int = 0):
        self.groups[group].isolate(i)

    def set_drop_prob(self, p: float):
        self.net.drop_prob = p

    def force_gc(self, drain: bool = True, max_ticks: int = 8000,
                 group: int = 0) -> bool:
        return self.groups[group].force_gc(drain, max_ticks)

    def hard_crash_from(self, exc) -> Optional[Tuple[int, int]]:
        """Map a mid-I/O SimulatedCrash to (group, node) and hard-crash
        that replica (the per-group workdirs are disjoint)."""
        for g, c in enumerate(self.groups):
            nid = c.hard_crash_from(exc)
            if nid is not None:
                return (g, nid)
        return None

    # ------------------------------------------------------- run shipping
    def drain_shipping(self, max_ticks: int = 4000) -> bool:
        return all(c.drain_shipping(max_ticks) for c in self.groups)

    def destroy(self):
        for c in self.groups:
            for e in c.engines:
                if e is not None:
                    e.close()
        shutil.rmtree(self.workdir, ignore_errors=True)
