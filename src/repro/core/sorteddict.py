"""Minimal pure-Python SortedDict — fallback for `sortedcontainers`.

The container image does not ship `sortedcontainers`; MiniLSM only needs a
small slice of its API (sorted iteration, bisect on keys, indexable key
view), so this drop-in keeps the engine importable everywhere.  When the
real package is installed it is preferred (see minilsm.py).
"""
from __future__ import annotations

from bisect import bisect_left, bisect_right, insort
from typing import Any, Iterator, List, Tuple


class SortedDict:
    """dict + sorted key list; O(n) insert for new keys, fine at repro scale."""

    def __init__(self):
        self._d: dict = {}
        self._keys: List[Any] = []

    # ----------------------------------------------------------- mutation
    def __setitem__(self, key, value):
        if key not in self._d:
            insort(self._keys, key)
        self._d[key] = value

    def __delitem__(self, key):
        del self._d[key]
        i = bisect_left(self._keys, key)
        del self._keys[i]

    def clear(self):
        self._d.clear()
        self._keys.clear()

    # ------------------------------------------------------------- lookup
    def __getitem__(self, key):
        return self._d[key]

    def get(self, key, default=None):
        return self._d.get(key, default)

    def __contains__(self, key) -> bool:
        return key in self._d

    def __len__(self) -> int:
        return len(self._d)

    def __bool__(self) -> bool:
        return bool(self._d)

    def __iter__(self) -> Iterator:
        return iter(self._keys)

    # ------------------------------------------------- sorted-view extras
    def keys(self) -> List[Any]:
        return self._keys

    def items(self) -> List[Tuple[Any, Any]]:
        return [(k, self._d[k]) for k in self._keys]

    def values(self) -> List[Any]:
        return [self._d[k] for k in self._keys]

    def bisect_left(self, key) -> int:
        return bisect_left(self._keys, key)

    def bisect_right(self, key) -> int:
        return bisect_right(self._keys, key)
