"""In-process Raft cluster: N nodes, each with its own engine directory and
byte-accounted metrics; deterministic fault injection (crash / restart /
partition).

Client operations are thin wrappers over the consistency-tiered
NezhaClient (repro.core.client): writes loop-retry through the leader,
reads default to LINEARIZABLE (ReadIndex) and accept
`consistency=`/`session=`/`node=` for the LEASE and SESSION tiers —
`Cluster.get`/`scan` no longer touch any engine directly, because a
deposed leader's engine can serve stale state (see
tests/test_client_reads.py for the regression that proves it).

Recovery semantics: a restarted node reloads its engine from disk
(engine.recover()), reconstructs the Raft log tail, and re-applies committed
entries — exactly the replay the paper times in Fig. 11 (Nezha replays
lightweight offsets, Original replays full values through the WAL path).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

from repro.core import faultfs
from repro.core import trace as _trace
from repro.core.client import NezhaClient, Session
from repro.core.engines import ENGINES, NezhaEngine
from repro.core.faultfs import write_json_atomic
from repro.core.metrics import Metrics
from repro.core.raft import LEADER, RaftNode
from repro.core.shipping import RunAdopter, RunShipper
from repro.core.simnet import SimNet


class Cluster:
    def __init__(self, n: int = 3, engine: str = "nezha", workdir: str = "",
                 seed: int = 0, sync: bool = False, leader_hint: int = 0,
                 engine_kwargs: Optional[dict] = None, heartbeat_every: int = 5,
                 election_timeout=(20, 40), max_batch: int = 64,
                 drop_prob: float = 0.0, lease_ticks: Optional[int] = None,
                 default_consistency: str = "linearizable",
                 recover: bool = False, promote_lag: int = 16,
                 auto_promote: bool = True,
                 group: Optional[int] = None,
                 net: Optional[SimNet] = None):
        self.engine_name = engine
        self.workdir = workdir
        self.seed = seed
        self.sync = sync
        self.engine_kwargs = engine_kwargs or {}
        self.heartbeat_every = heartbeat_every
        self.election_timeout = election_timeout
        self.max_batch = max_batch
        self.lease_ticks = lease_ticks
        self.promote_lag = promote_lag
        self.auto_promote = auto_promote
        os.makedirs(workdir, exist_ok=True)
        # membership state: ids removed from the config (their address is
        # dead forever) and, per node, the config it was CONSTRUCTED with
        # — the recovery fallback when a node crashed before persisting
        # any raft meta.  Both live in the cluster manifest so a full
        # restart (recover=True) rebuilds the right shape.
        self.removed: set = set()
        self._construct_cfg: Dict[int, dict] = {}
        if recover:
            man = self._load_manifest()
            if man is not None:
                n = man["n"]
                self.removed = set(man.get("removed", []))
                self._construct_cfg = {int(k): dict(v) for k, v in
                                       man.get("configs", {}).items()}
        self.n = n
        # Multi-Raft: `group` scopes this cluster to one shard consensus
        # group of a larger fabric (repro/core/shards.py).  With group
        # set, wire addresses become (group, nid) and the SimNet is
        # usually SHARED — we register our addresses on it but do not own
        # its clock: tick() is delegated to the fabric owner
        # (_tick_parent) so local wait loops (elect, client retries,
        # drain_shipping) keep every group's nodes live.
        self.group = group
        self._owns_net = net is None
        self._tick_parent = None
        if net is None:
            self.net = SimNet([self.addr(i) for i in range(n)], seed=seed,
                              drop_prob=drop_prob)
        else:
            self.net = net
            for i in range(n):
                self.net.add_node(self.addr(i))
        for r in self.removed:
            self.net.remove_node(self.addr(r))
        self.metrics: List[Metrics] = [Metrics(node=self.addr(i))
                                       for i in range(n)]
        self.engines: List = [None] * n
        self.nodes: List[Optional[RaftNode]] = [None] * n
        self.leader_hint = leader_hint
        # recover=True: full-cluster restart — every node rebuilds from
        # whatever its directory holds (the durability-gate path; workdir
        # must be a previous cluster's workdir)
        for i in range(n):
            if i in self.removed:
                continue        # a removed member stays removed
            self._make_node(i, fresh=not recover)
        self._save_manifest()
        self.client = NezhaClient(self,
                                  default_consistency=default_consistency)

    # ------------------------------------------------------------ plumbing
    def addr(self, i: int):
        """Wire address of local node id i on the (possibly shared) net."""
        return i if self.group is None else (self.group, i)

    def _local_ids(self, addrs) -> List[int]:
        """Filter wire addresses down to THIS group's local ids — keeps
        shared-net health reports per-group (and sortable)."""
        if self.group is None:
            return [a for a in addrs if not isinstance(a, tuple)]
        return [a[1] for a in addrs
                if isinstance(a, tuple) and a[0] == self.group]

    def _engine_dir(self, i: int) -> str:
        return os.path.join(self.workdir, f"node{i}")

    def _manifest_path(self) -> str:
        return os.path.join(self.workdir, "cluster.json")

    def _save_manifest(self):
        write_json_atomic(self._manifest_path(), {
            "n": self.n,
            "removed": sorted(self.removed),
            "configs": {str(i): c for i, c in self._construct_cfg.items()},
        })

    def _load_manifest(self) -> Optional[dict]:
        p = self._manifest_path()
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return json.load(f)

    def _make_node(self, i: int, fresh: bool,
                   voters: Optional[List[int]] = None,
                   learners: Optional[List[int]] = None):
        cls = ENGINES[self.engine_name]
        eng = cls(self._engine_dir(i), self.metrics[i], sync=self.sync,
                  is_leader=(lambda i=i: i == self.leader_hint),
                  **self.engine_kwargs)
        self.engines[i] = eng
        eto = self.election_timeout
        if fresh:
            self._construct_cfg[i] = {
                "voters": sorted(voters) if voters is not None
                else sorted(range(self.n)),
                "learners": sorted(learners or [])}
        cc = self._construct_cfg.get(i)
        node = RaftNode(
            i, list(range(self.n)), self.net, eng, eng.apply,
            apply_batch_fn=getattr(eng, "apply_batch", None),
            seed=self.seed, election_timeout=eto,
            heartbeat_every=self.heartbeat_every,
            max_batch=self.max_batch,
            lease_ticks=self.lease_ticks,
            snapshot_fn=eng.snapshot,
            install_snapshot_fn=getattr(eng, "install_snapshot", None),
            voters=(cc["voters"] if cc else None),
            learners=(cc["learners"] if cc else None),
            promote_lag=self.promote_lag,
            auto_promote=self.auto_promote,
            group=self.group)
        node.metrics = self.metrics[i]   # read-tier evidence (quorum rounds)
        # deterministic first leader: the hinted node's FIRST deadline
        # fires early; every later reset uses the full election timeout.
        # (Permanently halving its timeout — the old scheme — would let a
        # node stand for election inside another leader's lease window,
        # which must stay < the minimum election timeout to be safe.)
        # Fresh construction only: a RESTARTED hint node must come back
        # with the full timeout for exactly the same reason.
        if fresh and i == self.leader_hint:
            node.election_deadline = self.net.time + \
                node.rng.randint(eto[0] // 2, eto[0] // 2 + 2)
        if isinstance(eng, NezhaEngine):
            eng.on_snapshot = node.compact_to
            if eng.run_shipping:
                # replication tier 2: the leader's sealed runs stream to
                # followers as adoption records instead of each node
                # re-running GC (see repro/core/shipping.py)
                node.shipper = RunShipper(node, eng, self.metrics[i])
                node.adopter = RunAdopter(node, eng, self.metrics[i])
                eng.ship_hook = node.shipper.on_run_sealed
                eng.raft_role = (lambda node=node: node.role == LEADER)
        self.nodes[i] = node
        if not fresh:
            # restart vote stickiness: before crashing, this node's probe
            # acks may have renewed a lease that is STILL live, but its
            # in-memory last-leader-contact is gone.  Treat startup as
            # leader contact so it disregards RequestVote for one minimum
            # election timeout (>= any lease it could have renewed) —
            # otherwise a restarted follower could vote a rival leader in
            # mid-lease and a LEASE read on the old leader would be stale.
            node._last_leader_contact = self.net.time
            entries, offsets, si, st = eng.recover()
            node.entries = list(entries)
            node.offsets = list(offsets)
            node.snap_index = si
            node.snap_term = st
            node.commit_index = si
            node.last_applied = si
            term, vote, cfg = eng.load_meta()
            node.current_term, node.voted_for = term, vote
            # membership survives restart: persisted meta config as the
            # base, plus any KIND_CONFIG entries in the recovered log tail
            node.restore_config(cfg)
            # a recovered node's durability predates the tracer's view of
            # it — without these baseline events the causality auditor
            # would flag its first post-restart ack as ack-before-durable
            self._baseline_events(node)

    def _baseline_events(self, node: RaftNode):
        """Emit audit baseline for state that became durable/committed/
        applied before (or outside) the tracer's window."""
        t = _trace.active()
        if t is None:
            return
        last = node.entries[-1].index if node.entries else node.snap_index
        if last > 0:
            t.event("durable", node.addr, last, baseline=True)
        if node.commit_index > 0:
            t.event("commit_learned", node.addr, node.commit_index,
                    baseline=True)
        if node.last_applied > 0:
            t.event("apply", node.addr, node.last_applied, baseline=True)
        if node.role == LEADER:
            # seed the acked map: commits after a mid-run install may
            # rest on match_index earned before the tracer was watching
            for p, m in sorted(node.match_index.items()):
                if p != node.nid and m > 0:
                    t.event("ack_recv", node.addr, m, baseline=True,
                            **{"from": node._addr(p)})

    # --------------------------------------------------------------- tracing
    def enable_tracing(self) -> "_trace.Tracer":
        """Install a process-global virtual-time tracer driven by this
        cluster's SimNet clock and seed it with baseline audit events for
        every live node (state that became durable before the tracer
        existed must not read as ack-before-durable).  Returns the
        tracer; pair with disable_tracing()."""
        t = _trace.Tracer(clock=lambda: self.net.time)
        _trace.install(t)
        for nd in self.nodes:
            if nd is not None:
                self._baseline_events(nd)
        return t

    def disable_tracing(self) -> Optional["_trace.Tracer"]:
        t = _trace.active()
        _trace.uninstall()
        return t

    def registry(self, reg: Optional["_trace.MetricsRegistry"] = None,
                 **extra: str) -> "_trace.MetricsRegistry":
        """Fill a labeled MetricsRegistry from every node's Metrics plus
        cluster-level gauges (liveness, Raft progress, SimNet traffic) —
        the structured successor to health_report()'s ad-hoc dicts.
        `extra` adds constant labels to every sample (ShardedCluster
        passes shard=<g> and merges all groups into one registry);
        net-wide counters are emitted only by the net's owner so a
        shared fabric isn't double-counted."""
        reg = reg if reg is not None else _trace.MetricsRegistry()
        for i, m in enumerate(self.metrics):
            m.fill_registry(reg, node=str(i), **extra)
        lab = sorted(("node",) + tuple(extra))
        up = reg.gauge("repro_node_up", "node is running and reachable",
                       lab)
        term = reg.gauge("repro_raft_term", "current raft term", lab)
        commit = reg.gauge("repro_raft_commit_index",
                           "highest committed log index", lab)
        applied = reg.gauge("repro_raft_last_applied",
                            "highest applied log index", lab)
        for i, nd in enumerate(self.nodes):
            alive = nd is not None and self.addr(i) not in self.net.down
            up.labels(node=str(i), **extra).set(1 if alive else 0)
            if nd is not None:
                term.labels(node=str(i), **extra).set(nd.current_term)
                commit.labels(node=str(i), **extra).set(nd.commit_index)
                applied.labels(node=str(i), **extra).set(nd.last_applied)
        if self._owns_net:
            sent = reg.counter("repro_net_msgs_total",
                               "simnet messages by outcome", ["outcome"])
            sent.labels(outcome="sent").inc(self.net.sent_msgs)
            sent.labels(outcome="dropped").inc(self.net.dropped_msgs)
            drops = reg.counter("repro_net_drops_total",
                                "simnet drops by reason", ["reason"])
            for reason, cnt in sorted(self.net.drop_reasons.items()):
                drops.labels(reason=reason).inc(cnt)
        return reg

    def prometheus_text(self) -> str:
        return self.registry().prometheus_text()

    def scrape(self) -> dict:
        return self.registry().scrape()

    # ---------------------------------------------------------------- time
    def tick(self, k: int = 1):
        if self._tick_parent is not None:
            # shared fabric: the shard owner advances net time ONCE per
            # step and ticks EVERY group's nodes, so any group's local
            # wait loop keeps the whole fabric live
            self._tick_parent.tick(k)
            return
        for _ in range(k):
            self.net.tick()
            for node in self.nodes:
                if node is not None:
                    node.tick()

    def leader(self) -> Optional[RaftNode]:
        live = [nd for i, nd in enumerate(self.nodes)
                if nd is not None and self.addr(i) not in self.net.down
                and i not in self.removed]
        leaders = [nd for nd in live if nd.role == LEADER]
        if not leaders:
            return None
        return max(leaders, key=lambda nd: nd.current_term)

    def elect(self, max_ticks: int = 2000) -> RaftNode:
        for _ in range(max_ticks):
            ld = self.leader()
            if ld is not None and ld.commit_index >= ld.snap_index:
                return ld
            self.tick()
        raise TimeoutError("no leader elected")

    # --------------------------------------------------------- membership
    # Self-healing surface: single-server config changes through the Raft
    # log (raft.py).  add_node joins a non-voting learner that catches up
    # via InstallSnapshot + run shipping; the leader auto-promotes it once
    # its applied index is within promote_lag of the commit index;
    # remove_node retires an id forever (its SimNet address dies with it).
    def add_node(self, *, max_ticks: int = 8000) -> int:
        """Join a fresh node as a LEARNER; returns its id once the
        add-learner config entry has committed and the node is running."""
        nid = self.n
        self.n += 1
        self.net.add_node(self.addr(nid))
        self.metrics.append(Metrics(node=self.addr(nid)))
        self.engines.append(None)
        self.nodes.append(None)
        self.elect()
        voters = learners = None
        for _ in range(max_ticks):
            ld = self.leader()
            if ld is not None:
                if nid in ld.learners and ld.config_index <= ld.commit_index:
                    voters = sorted(ld.voters)
                    learners = sorted(ld.learners)
                    break
                ld.propose_add_learner(nid)   # no-op while one's in flight
            self.tick()
        else:
            raise TimeoutError("add_node: add-learner config never "
                               "committed")
        # construct the node with the COMMITTED config: it knows who may
        # lead (rejecting stale candidates) and that it must not campaign
        self._make_node(nid, fresh=True, voters=voters, learners=learners)
        self._save_manifest()
        return nid

    def wait_promoted(self, nid: int, max_ticks: int = 20000) -> bool:
        """Tick until the leader has auto-promoted `nid` to voter and the
        promote config entry has committed."""
        for _ in range(max_ticks):
            ld = self.leader()
            if ld is not None and nid in ld.voters and \
                    ld.config_index <= ld.commit_index:
                return True
            self.tick()
        return False

    def remove_node(self, nid: int, *, max_ticks: int = 8000):
        """Remove `nid` from the config (voter or learner, live or dead).
        A live leader removes itself gracefully: leadership is transferred
        to the best-caught-up voter first (TimeoutNow), with leader-
        proposed self-removal + step-down as the fallback."""
        ld = self.elect()
        if ld.nid == nid and len(ld.voters) > 1:
            ld.transfer_leadership()
            for _ in range(max_ticks):
                cur = self.leader()
                if cur is not None and cur.nid != nid and \
                        cur.commit_index >= cur.snap_index:
                    break
                self.tick()
        done = False
        for _ in range(max_ticks):
            ld = self.leader()
            if ld is not None:
                if ld.nid != nid and nid not in ld.voters and \
                        nid not in ld.learners and \
                        ld.config_index <= ld.commit_index:
                    done = True
                    break
                ld.propose_remove(nid)
            self.tick()
        if not done:
            raise TimeoutError("remove_node: removal config never "
                               "committed")
        # the id is retired: shut the process down and kill its address —
        # queued + future mail is destroyed (counted in dropped_msgs)
        self.removed.add(nid)
        if self.engines[nid] is not None:
            self.engines[nid].close()
        self.nodes[nid] = None
        self.engines[nid] = None
        self.net.remove_node(self.addr(nid))
        self._save_manifest()

    def replace_node(self, dead: int, *, max_ticks: int = 20000) -> int:
        """Self-healing cycle (the smoke-gate scenario): ensure `dead` is
        down, join a fresh learner, wait for snapshot + run-shipping
        catch-up to auto-promote it, then retire the dead id.  Quorum is
        restored at the original voter count; returns the new node id."""
        if self.nodes[dead] is not None:
            self.crash(dead)
        new = self.add_node(max_ticks=max_ticks)
        if not self.wait_promoted(new, max_ticks=max_ticks):
            raise TimeoutError(f"replace_node: learner {new} never "
                               "promoted")
        self.remove_node(dead, max_ticks=max_ticks)
        return new

    # -------------------------------------------------------------- client
    # Thin wrappers over the consistency-tiered client: the leadership-
    # change retry loop, ReadIndex round, lease check and session routing
    # all live in repro.core.client — not here, and not in each test.
    def put(self, key: bytes, value: bytes, max_ticks: int = 2000) -> int:
        return self.client.put(key, value, max_ticks=max_ticks)

    def put_many(self, items, window: int = 64, max_ticks: int = 200000,
                 batch: Optional[int] = None):
        return self.client.put_many(items, window=window,
                                    max_ticks=max_ticks, batch=batch)

    def get(self, key: bytes, consistency: Optional[str] = None, *,
            session: Optional[Session] = None,
            node: Optional[int] = None) -> Optional[bytes]:
        return self.client.get(key, consistency, session=session, node=node)

    def scan(self, lo: bytes, hi: bytes, consistency: Optional[str] = None,
             *, session: Optional[Session] = None,
             node: Optional[int] = None):
        return self.client.scan(lo, hi, consistency, session=session,
                                node=node)

    def session(self) -> Session:
        return self.client.session()

    def read_report(self) -> List[dict]:
        """Per-node consistency-tier evidence: reads served by tier, the
        quorum rounds paid (LINEARIZABLE / lapsed-lease fallback), reads
        followers served (SESSION's new read capacity) and session reads
        that stalled on the apply pipeline.  Shared by benchmarks/
        fig_reads.py, the smoke gate and the stale-read tests."""
        ld = self.leader()
        return [{
            "node": i,
            "role": "leader" if ld is not None and i == ld.nid
                    else "follower",
            "tiers": dict(m.read_tiers),
            "quorum_rounds": m.read_quorum_rounds,
            "follower_serves": m.follower_serves,
            "session_stalls": m.session_stalls,
        } for i, m in enumerate(self.metrics)]

    # ------------------------------------------------------- run shipping
    def drain_shipping(self, max_ticks: int = 4000) -> bool:
        """Tick until every live follower's durable ship position reaches
        the leader's newest sealed record (True), or the budget runs out.
        Also waits for the apply pipeline so scans are comparable."""
        for _ in range(max_ticks):
            ld = self.leader()
            if ld is not None:
                caught_up = all(
                    self.nodes[p] is None or
                    self.addr(p) in self.net.down or
                    self.nodes[p].last_applied >= ld.commit_index
                    for p in ld.peers)
                shipped = True
                if ld.shipper is not None and ld.shipper.records:
                    tip = ld.shipper.records[-1][0]
                    shipped = all(
                        self.addr(p) in self.net.down or
                        self.nodes[p] is None or
                        (ld.shipper.peers.get(p) is not None and
                         ld.shipper.peers[p].pos >= tip)
                        for p in ld.peers)
                if caught_up and shipped:
                    return True
            self.tick()
        return False

    def replication_report(self) -> List[dict]:
        """Per-node replication + GC byte accounting (run-shipping
        evidence: follower gc_flush_bytes ~ 0 when adoption is on)."""
        ld = self.leader()
        out = []
        for i, m in enumerate(self.metrics):
            eng = self.engines[i]
            out.append({
                "node": i,
                "role": "leader" if ld is not None and i == ld.nid
                        else "follower",
                "ship_bytes": dict(m.ship_bytes),
                "gc_flush_bytes": m.write_bytes.get("gc_sorted", 0),
                "gc_merge_bytes": m.write_bytes.get("gc_level_merge", 0),
                "adopt_bytes": m.write_bytes.get("run_adopt", 0),
                "adopted_runs": getattr(eng, "adopt_count", 0),
            })
        return out

    # --------------------------------------------------------------- health
    def health_report(self) -> dict:
        """One scrapeable document merging liveness, Raft progress, the
        network's fault state, read_report() and replication_report() —
        the /metrics analogue the ROADMAP's workload-harness item asks
        for.  The chaos harness snapshots it around every fault; anything
        external (a test, a dashboard, a future HTTP endpoint) reads this
        instead of poking node internals."""
        ld = self.leader()
        nodes = []
        for i, nd in enumerate(self.nodes):
            if nd is None:
                nodes.append({
                    "node": i, "up": False,
                    "membership": "removed" if i in self.removed
                    else "down"})
                continue
            if i in self.removed:
                membership = "removed"
            elif nd.is_voter:
                membership = "voter"
            elif nd.nid in nd.learners:
                membership = "learner"
            else:
                membership = "none"     # e.g. demoted but still running
            nodes.append({
                "node": i, "up": self.addr(i) not in self.net.down,
                "role": nd.role, "term": nd.current_term,
                "membership": membership,
                "config_index": nd.config_index,
                "commit_index": nd.commit_index,
                "last_applied": nd.last_applied,
                "lease_valid": nd.lease_valid(),
            })
        return {
            "time": self.net.time,
            "leader": ld.nid if ld is not None else None,
            "membership": {
                "voters": sorted(ld.voters) if ld is not None else None,
                "learners": sorted(ld.learners) if ld is not None else None,
                "config_index": ld.config_index if ld is not None else None,
                "removed": sorted(self.removed),
            },
            "nodes": nodes,
            "net": {"sent_msgs": self.net.sent_msgs,
                    "dropped_msgs": self.net.dropped_msgs,
                    "drop_reasons": dict(self.net.drop_reasons),
                    "drop_prob": self.net.drop_prob,
                    "down": sorted(self._local_ids(self.net.down)),
                    "removed": sorted(self._local_ids(self.net.removed)),
                    "partitions": [sorted(self._local_ids(p))
                                   for p in self.net.blocked
                                   if len(self._local_ids(p)) == len(p)]},
            "reads": self.read_report(),
            "replication": self.replication_report(),
            "faults": {
                "per_node": [dict(m.fault_injections) for m in self.metrics],
                "faultfs": (faultfs.active().counters()
                            if faultfs.active() is not None else None),
            },
            "metrics": self.scrape(),
        }

    # --------------------------------------------------------------- faults
    # The chaos scheduler (repro/core/workload.py) drives faults through
    # these hooks only — tests and schedules stay independent of SimNet
    # internals, and every hook is deterministic given the cluster seeds.
    def partition(self, a: int, b: int):
        self.net.partition(self.addr(a), self.addr(b))

    def heal(self, a: int = None, b: int = None):
        if a is None:
            if self.group is None:
                self.net.heal()
            else:
                # shared fabric: only discard partitions wholly inside
                # THIS group — other shards' faults are not ours to fix
                for p in list(self.net.blocked):
                    if len(self._local_ids(p)) == len(p):
                        self.net.blocked.discard(p)
        else:
            self.net.heal(self.addr(a), self.addr(b))

    def isolate(self, i: int):
        """Symmetric partition: cut every link touching node i."""
        for j in range(self.n):
            if j != i:
                self.net.partition(self.addr(i), self.addr(j))

    def set_drop_prob(self, p: float):
        """Net-wide lossy window (chaos 'lossy' action); 0 restores."""
        self.net.drop_prob = p

    def kill_leader(self, max_ticks: int = 2000) -> int:
        """Crash the current leader (electing one first if none is
        settled); returns its node id so the schedule can restart it."""
        ld = self.elect(max_ticks)
        self.crash(ld.nid)
        return ld.nid

    def force_gc(self, drain: bool = True, max_ticks: int = 8000) -> bool:
        """GC-storm hook: start a flush cycle on the leader's engine NOW,
        regardless of gc_threshold, and (by default) drain it plus any
        cascading level merges synchronously — the chaos scheduler uses
        it to pile GC work onto the serving path.  Returns False when the
        engine has no leveled GC (baseline engines) or the apply pipeline
        cannot catch up within max_ticks."""
        ld = self.elect()
        eng = self.engines[ld.nid]
        if not hasattr(eng, "run_gc_to_completion"):
            return False
        if eng.gc_completed and eng._merge is None:
            eng.start_gc()       # no-op on an empty active segment
        if drain:
            # gc_step parks at a barrier until the whole active segment
            # has APPLIED; tick raft forward while it lags, or a force_gc
            # issued right after a failover spins forever on a leader
            # whose apply pipeline is still replaying
            for _ in range(max_ticks):
                if not (eng.gc_started and not eng.gc_completed) or \
                        eng._gc_last[0] >= eng._gc_snapshot_point[0]:
                    break
                self.tick()
            else:
                return False
            eng.run_gc_to_completion()
        return True

    def crash(self, i: int):
        self.net.crash(self.addr(i))
        if self.engines[i] is not None:
            self.engines[i].close()
        self.nodes[i] = None
        self.engines[i] = None

    def crash_hard(self, i: int):
        """kill -9: the node is dropped WITHOUT engine.close() — nothing
        buffered gets a goodbye flush — and the installed FaultFS rewrites
        the node's directory down to its durable view (torn tails and
        all).  Falls back to crash() when no FaultFS is installed (then
        there is no unsynced state to model)."""
        fs = faultfs.active()
        if fs is None:
            return self.crash(i)
        self.net.crash(self.addr(i))
        self.nodes[i] = None
        self.engines[i] = None      # dropped un-closed on purpose
        fs.materialize(self._engine_dir(i) + os.sep)
        self.metrics[i].on_fault("hard_crash")

    def hard_crash_from(self, exc) -> Optional[int]:
        """Map a SimulatedCrash raised mid-I/O to the node whose directory
        the op touched, hard-crash that node, and return its id (None if
        the path maps to no node)."""
        p = os.path.abspath(exc.path)
        for i in range(self.n):
            d = os.path.abspath(self._engine_dir(i))
            if p == d or p.startswith(d + os.sep):
                if self.nodes[i] is not None:
                    self.crash_hard(i)
                    self.metrics[i].on_fault("mid_op_crash")
                return i
        return None

    def restart(self, i: int) -> float:
        """Returns wall-clock recovery seconds (Fig. 11 measurement)."""
        t0 = time.perf_counter()
        self._make_node(i, fresh=False)
        dt = time.perf_counter() - t0
        self.net.restart(self.addr(i))
        return dt

    def destroy(self):
        for e in self.engines:
            if e is not None:
                e.close()
        shutil.rmtree(self.workdir, ignore_errors=True)
