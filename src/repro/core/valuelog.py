"""Append-only ValueLog — the single persistence point of KVS-Raft.

Entry layout (little-endian):
    u32 magic | u32 term | u64 index | u8 kind | u16 key_len | u32 val_len
    key bytes | value bytes
The (term, index) consensus metadata is serialized WITH the value (paper
§III-B step 3): one append persists both the Raft log entry and the value.
``append`` returns the byte offset, which is the only thing the state machine
keeps.
"""
from __future__ import annotations

import os
import struct
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

from repro.core.cache import BlockCache, next_namespace
from repro.core.faultfs import fs_fsync, fs_open, fs_remove
from repro.core.metrics import Metrics

_HDR = struct.Struct("<IIQBHI")
MAGIC = 0x4E5A4841  # "NZHA"

KIND_PUT = 1
KIND_NOOP = 2
KIND_SNAP = 3
KIND_CONFIG = 4   # membership change entry: value = JSON {voters, learners}


@dataclass
class LogEntry:
    term: int
    index: int
    kind: int
    key: bytes
    value: bytes

    def encode(self) -> bytes:
        return _HDR.pack(MAGIC, self.term, self.index, self.kind,
                         len(self.key), len(self.value)) + self.key + self.value

    @staticmethod
    def decode(buf: bytes, off: int = 0) -> Tuple["LogEntry", int]:
        magic, term, index, kind, klen, vlen = _HDR.unpack_from(buf, off)
        assert magic == MAGIC, f"corrupt entry at {off}"
        s = off + _HDR.size
        key = buf[s:s + klen]
        value = buf[s + klen:s + klen + vlen]
        return LogEntry(term, index, kind, key, value), s + klen + vlen


class ValueLog:
    """Append-only file of LogEntry records with offset-addressed reads."""

    def __init__(self, path: str, metrics: Metrics, category: str = "valuelog",
                 sync: bool = False, group_commit: bool = False,
                 cache: Optional[BlockCache] = None):
        self.path = path
        self.metrics = metrics
        self.category = category
        self.sync = sync
        self.group_commit = group_commit
        self.cache = cache
        self._cache_ns = next_namespace()
        self._dirty = False
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = fs_open(path, "ab+")
        self._f.seek(0, os.SEEK_END)
        self._size = self._f.tell()

    # ------------------------------------------------------------- writes
    def append(self, entry: LogEntry) -> int:
        data = entry.encode()
        off = self._size
        self._f.write(data)
        self._size += len(data)
        self._dirty = True
        if self.sync and not self.group_commit:
            self.sync_now()
        self.metrics.on_write(self.category, len(data))
        return off

    def append_batch(self, entries: List[LogEntry]) -> List[int]:
        """Group commit: ONE buffered write (and, under sync, one fsync via
        sync_now at the commit-window boundary) for the whole batch.  Byte
        accounting stays per-record so write-amplification ratios are
        unchanged — only the fsync count drops."""
        offs: List[int] = []
        chunks: List[bytes] = []
        off = self._size
        for e in entries:
            data = e.encode()
            offs.append(off)
            chunks.append(data)
            off += len(data)
            self.metrics.on_write(self.category, len(data))
        if chunks:
            self._f.write(b"".join(chunks))
            self._size = off
            self._dirty = True
            if self.sync and not self.group_commit:
                self.sync_now()
        return offs

    def sync_now(self):
        """Commit-window boundary: flush + fsync once if anything is dirty."""
        if not self._dirty:
            return
        self._f.flush()
        if self.sync:
            fs_fsync(self._f)
            self.metrics.on_fsync(self.category)
        self._dirty = False

    def flush(self):
        self._f.flush()

    # -------------------------------------------------------------- reads
    def read_at(self, offset: int) -> LogEntry:
        if self.cache is not None:
            rec = self.cache.get(self._cache_ns, offset)
            if rec is not None:
                self.metrics.on_cache_hit(self.category)
                entry, _ = LogEntry.decode(rec, 0)
                return entry
        self._f.flush()
        # persistent handle: append-mode writes always land at EOF, so the
        # write handle doubles as the read handle (no per-read open())
        self._f.seek(offset)
        hdr = self._f.read(_HDR.size)
        magic, term, index, kind, klen, vlen = _HDR.unpack(hdr)
        assert magic == MAGIC, f"corrupt entry at {offset}"
        body = self._f.read(klen + vlen)
        self._f.seek(0, os.SEEK_END)
        self.metrics.on_read(self.category, _HDR.size + klen + vlen)
        if self.cache is not None:
            self.cache.put(self._cache_ns, offset, hdr + body)
        return LogEntry(term, index, kind, body[:klen], body[klen:])

    def read_value_at(self, offset: int) -> bytes:
        return self.read_at(offset).value

    def scan(self) -> Iterator[Tuple[int, LogEntry]]:
        """Sequential scan of (offset, entry) — recovery / GC path."""
        self._f.flush()
        with open(self.path, "rb") as f:
            buf = f.read()
        self.metrics.on_read(self.category + "_seq", len(buf))
        off = 0
        while off < len(buf):
            entry, nxt = LogEntry.decode(buf, off)
            yield off, entry
            off = nxt

    def scan_headers(self) -> Iterator[Tuple[int, LogEntry]]:
        """Header-only scan: seeks past values (KVS-Raft recovery — the
        state machine replays (key, offset) pairs, so values need never be
        read; this is the mechanism behind the paper's Fig. 11 win).
        Yielded entries carry value=b'' and must be hydrated via read_at()
        before being shipped to a follower."""
        self._f.flush()
        with open(self.path, "rb") as f:
            off = 0
            while True:
                hdr = f.read(_HDR.size)
                if len(hdr) < _HDR.size:
                    break
                magic, term, index, kind, klen, vlen = _HDR.unpack(hdr)
                assert magic == MAGIC, f"corrupt entry at {off}"
                key = f.read(klen)
                f.seek(vlen, os.SEEK_CUR)
                self.metrics.on_read(self.category + "_hdr",
                                     _HDR.size + klen)
                e = LogEntry(term, index, kind, key, b"")
                e.value_len = vlen  # type: ignore[attr-defined]
                yield off, e
                off += _HDR.size + klen + vlen

    def repair_tail(self) -> int:
        """Crash hygiene: drop torn/corrupt trailing bytes.  Must run before
        any recovery scan — scan()/scan_headers() assert on magic.  Safe by
        the durability contract: with sync=True every acked entry was
        fsynced before the ack, so a torn tail is by construction unacked.
        Returns the number of bytes dropped."""
        self._f.flush()
        size = os.path.getsize(self.path)
        end = 0
        with open(self.path, "rb") as f:
            while True:
                hdr = f.read(_HDR.size)
                if len(hdr) < _HDR.size:
                    break
                magic, _, _, _, klen, vlen = _HDR.unpack(hdr)
                if magic != MAGIC:
                    break
                if end + _HDR.size + klen + vlen > size:
                    break
                f.seek(klen + vlen, os.SEEK_CUR)
                end += _HDR.size + klen + vlen
        dropped = size - end
        if dropped:
            self.truncate_to(end)
        else:
            self._size = size
        return dropped

    @property
    def size(self) -> int:
        return self._size

    def truncate_to(self, offset: int):
        """Drop the tail from `offset` (Raft conflict resolution)."""
        self._f.flush()
        self._f.truncate(offset)
        self._f.seek(0, os.SEEK_END)
        self._size = offset
        self._dirty = True
        if self.cache is not None:   # cached records past `offset` are stale
            self.cache.invalidate(self._cache_ns)
            self._cache_ns = next_namespace()

    def close(self):
        try:
            self._f.close()
        except Exception:
            pass

    def delete(self):
        self.close()
        if self.cache is not None:
            self.cache.invalidate(self._cache_ns)
        fs_remove(self.path)
