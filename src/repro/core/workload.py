"""Open-loop workload harness + deterministic chaos scheduler + checker.

Every closed-loop benchmark in benchmarks/ measures mean ops/s: issue an
op, wait, issue the next.  Production traffic is OPEN-LOOP — requests
arrive on their own schedule (a Poisson process), pile up behind a slow
server, and are judged on tail quantiles, not means.  The classic trap
(coordinated omission) is that a closed-loop driver silently stops
offering load exactly when the system stalls — a leader election that
freezes the store for 50ms costs ONE closed-loop sample but delays every
open-loop arrival that lands inside the stall.  This module is the
harness that measures the difference, and the chaos scheduler that makes
the stalls happen on purpose:

  * WorkloadSpec / Tenant: Poisson arrivals at a target rate, Zipfian
    hot-key skew, YCSB A-F read/write/scan/RMW mixes (extending fig8),
    multi-tenant mixes with per-tenant consistency tiers (SESSION tenants
    carry a real client Session).
  * Open-loop reconstruction: ops execute sequentially against the
    cluster (it is a single-process discrete-event sim) and their wall
    clock service times are replayed against the arrival schedule:
        start_i      = max(arrival_i, completion_{i-1})
        completion_i = start_i + service_i
        latency_i    = completion_i - arrival_i   (queue + service)
    which is exactly the coordinated-omission correction: an op stuck
    behind a failover inflates the latency of every queued arrival.
  * LatencyHistogram (metrics.py) per (tenant, op, tier) and per phase
    (steady / fault / recovered), with the queue-delay vs service-time
    split recorded separately.
  * ChaosSchedule: seeded, deterministic fault scripts — leader kill +
    restart, leader isolation (symmetric) and single-link partitions,
    net-wide `drop_prob` lossy windows, GC storms (forced flush+merge
    cycles) — fired at op-index points so the timeline is replayable
    from {seed, schedule} alone (recorded into every report/artifact).
  * Crash-point probes: run_crashpoint() replays a seeded single-node
    workload under an installed FaultFS (repro.core.faultfs), applies
    kill -9 semantics at I/O op k (drop / torn / lost_rename), recovers
    the node from its durable view and audits for acked-write loss plus
    manifest/run-set/raft-log integrity; run_full_restart() does the
    same to ALL n nodes at once (fleet power loss) and additionally
    requires byte-equal engine scans after restart;
    run_membership_crashpoint() sweeps the config-change commit window
    (add learner -> promote -> remove voter) and additionally requires
    one committed config across the members and one leader per term
    across the crash boundary.  Three chaos actions
    (kill_leader_mid_put, crash_mid_gc, crash_mid_adoption) arm the same
    shim MID-operation, so the op loop treats an escaping
    SimulatedCrash as a node death — hard-crash + ack-ambiguity
    resolution — never as a harness error.
  * check_history(): every run's history is checked for linearizability
    violations (a LINEARIZABLE/LEASE read must return the latest acked
    write — a sequential client makes this exact, not heuristic) and for
    session-guarantee violations (read-your-writes + monotonic reads per
    key), reusing the same per-key write-sequence bookkeeping the
    session-token machinery implements cluster-side.

Determinism: every decision that touches the cluster (op kinds, keys,
values, fault points, fault targets) derives from the spec/schedule seeds
and the cluster's own seeded RNGs; wall-clock only feeds the histograms
— and WorkloadSpec(virtual_time=True) removes even that: service times
are measured in SimNet ticks * tick_us, so tail quantiles are themselves
deterministic and immune to CPU steal on a loaded host.
Same seeds => identical fault timeline AND identical SimNet delivery
order (tests/test_chaos_harness.py pins both).
"""
from __future__ import annotations

import os
import random
from dataclasses import asdict, dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core import faultfs
from repro.core import trace as _trace
from repro.core.client import (LEASE, LINEARIZABLE, SESSION, Session,
                               StaleReadError)
from repro.core.faultfs import SimulatedCrash
from repro.core.metrics import LatencyHistogram

# ---------------------------------------------------------------- workloads
# YCSB-style op mixes (fractions must sum to <= 1; the remainder is reads).
# `insert` routes the write fraction to NEW keys (D/E's growing keyspace).
MIXES: Dict[str, dict] = {
    "load": dict(write=1.00, scan=0.00, rmw=0.00, insert=True),
    "A":    dict(write=0.50, scan=0.00, rmw=0.00, insert=False),
    "B":    dict(write=0.05, scan=0.00, rmw=0.00, insert=False),
    "C":    dict(write=0.00, scan=0.00, rmw=0.00, insert=False),
    "D":    dict(write=0.05, scan=0.00, rmw=0.00, insert=True),
    "E":    dict(write=0.05, scan=0.95, rmw=0.00, insert=True),
    "F":    dict(write=0.00, scan=0.00, rmw=0.50, insert=False),
}

PHASES = ("steady", "fault", "recovered")


@dataclass
class Tenant:
    """One traffic class: a weight (share of arrivals), a YCSB mix and a
    consistency tier.  SESSION tenants get a real client Session, so their
    reads exercise follower serving + token stalls."""
    name: str = "default"
    weight: float = 1.0
    mix: str = "B"
    tier: str = LINEARIZABLE

    def mix_spec(self) -> dict:
        if isinstance(self.mix, dict):
            return self.mix
        return MIXES[self.mix]


@dataclass
class WorkloadSpec:
    rate: float = 2000.0       # open-loop arrivals per second
    n_ops: int = 400
    n_keys: int = 200          # preloaded keyspace
    vsize: int = 256
    zipf_theta: float = 1.2    # numpy zipf 'a' parameter (hot-key skew)
    scan_span: int = 20        # keys per scan
    seed: int = 0
    tenants: Tuple[Tenant, ...] = (Tenant(),)
    # virtual_time: service times come from SimNet ticks (tick_us each)
    # instead of perf_counter — fully deterministic tail quantiles
    virtual_time: bool = False
    tick_us: float = 50.0

    def record(self) -> dict:
        d = asdict(self)
        d["tenants"] = [asdict(t) for t in self.tenants]
        return d


def _key(i: int) -> bytes:
    return b"wk%08d" % i


def _value(key: bytes, wseq: int, vsize: int) -> bytes:
    """Deterministic, per-write-unique value: the key + a global write
    sequence number, padded to vsize — a stale read names exactly which
    write it resurrected."""
    stamp = b"%s:%08d:" % (key, wseq)
    return stamp + b"x" * max(0, vsize - len(stamp))


def zipf_key_indices(n_ops: int, n_keys: int, theta: float, seed: int):
    """Deterministic Zipfian key choices (hot-head skew), 0-based."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = []
    need = n_ops
    while need > 0:
        draw = rng.zipf(theta, size=max(2 * need, 64))
        draw = draw[draw <= n_keys][:need]
        out.append(draw)
        need -= len(draw)
    return (np.concatenate(out)[:n_ops] - 1).astype(int)


# ------------------------------------------------------------------- chaos
# Fault actions, all routed through Cluster's fault hooks:
#   kill_leader      crash the current leader (remembers who for restart)
#   restart          restart the most recently killed node
#   isolate_leader   symmetric partition of the current leader
#   partition_link   cut one {a,b} link (arg encodes the pair, a*n+b)
#   heal             clear every partition
#   lossy            net-wide drop_prob window (arg = probability)
#   heal_lossy       end the lossy window
#   gc_storm         force a flush + cascading merges on the leader NOW
# Crash-DURING-op actions (need an installed FaultFS; they degrade to the
# nearest polite fault without one):
#   kill_leader_mid_put   arm the leader's value log: the next vlog write
#                         dies mid-put with kill -9 semantics
#   crash_mid_gc          arm the leader's run files (torn) and force a GC
#                         cycle — it dies inside the build/seal/swap window
#   crash_mid_adoption    arm a follower's run files (torn) and tick until
#                         an adoption record lands mid-install
# Membership action (opt-in; not in ChaosSchedule.generate's default kinds
# so pinned same-seed artifacts keep their schedules):
#   replace_random_node   kill a random live voter hard, join a fresh
#                         learner, wait for auto-promotion, retire the
#                         dead id — the full self-healing cycle under load
ACTIONS = ("kill_leader", "restart", "isolate_leader", "partition_link",
           "heal", "lossy", "heal_lossy", "gc_storm",
           "kill_leader_mid_put", "crash_mid_gc", "crash_mid_adoption",
           "replace_random_node")


@dataclass
class FaultEvent:
    at: float                 # position in the run: fraction of n_ops [0,1)
    action: str
    arg: float = 0.0
    recovery: bool = False    # marks the end of a disruption window
    group: Optional[int] = None   # shard group to target (ShardedCluster);
    #                               None = the cluster itself (or shard 0)

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown chaos action {self.action!r}")


class ChaosSchedule:
    """A seeded, deterministic fault script.  Events fire when the op
    index crosses `at * n_ops`, so the timeline is a pure function of
    {seed, schedule} + the cluster seeds — wall clock never moves a
    fault.  record() is the replayable artifact every bench/report logs."""

    def __init__(self, events: Sequence[FaultEvent], seed: int = 0):
        self.events = sorted(events, key=lambda e: e.at)
        self.seed = seed

    @classmethod
    def kill_and_recover(cls, at: float = 0.35, restart_at: float = 0.6,
                         seed: int = 0,
                         group: Optional[int] = None) -> "ChaosSchedule":
        """The canonical smoke cycle: one leader kill, one restart.
        `group` aims both events at one shard of a ShardedCluster."""
        return cls([FaultEvent(at, "kill_leader", group=group),
                    FaultEvent(restart_at, "restart", recovery=True,
                               group=group)],
                   seed=seed)

    @classmethod
    def generate(cls, seed: int, n_cycles: int = 2,
                 kinds: Sequence[str] = ("kill_leader", "isolate_leader",
                                         "lossy", "gc_storm"),
                 n_nodes: int = 3) -> "ChaosSchedule":
        """Deterministic random script: the run is split into n_cycles
        windows, each getting one fault in its first half and the
        matching recovery in its second half.  Same seed => identical
        script; different seeds diverge (pinned by test)."""
        rng = random.Random(f"chaos:{seed}")
        events: List[FaultEvent] = []
        for ci in range(n_cycles):
            lo = ci / n_cycles
            span = 1.0 / n_cycles
            kind = rng.choice(list(kinds))
            start = lo + span * rng.uniform(0.10, 0.40)
            stop = lo + span * rng.uniform(0.55, 0.85)
            if kind == "kill_leader":
                events += [FaultEvent(start, "kill_leader"),
                           FaultEvent(stop, "restart", recovery=True)]
            elif kind == "isolate_leader":
                events += [FaultEvent(start, "isolate_leader"),
                           FaultEvent(stop, "heal", recovery=True)]
            elif kind == "partition_link":
                a = rng.randrange(n_nodes)
                b = (a + 1 + rng.randrange(n_nodes - 1)) % n_nodes
                events += [FaultEvent(start, "partition_link",
                                      arg=a * n_nodes + b),
                           FaultEvent(stop, "heal", recovery=True)]
            elif kind == "lossy":
                events += [FaultEvent(start, "lossy",
                                      arg=rng.choice((0.05, 0.1, 0.2))),
                           FaultEvent(stop, "heal_lossy", recovery=True)]
            else:
                events.append(FaultEvent(start, "gc_storm", recovery=True))
        return cls(events, seed=seed)

    def record(self) -> dict:
        return {"seed": self.seed,
                "schedule": [asdict(e) for e in self.events]}


class _ChaosRunner:
    """Applies a schedule against a live cluster, op index by op index,
    and keeps the replayable timeline + the phase pointer."""

    def __init__(self, cluster, schedule: ChaosSchedule, n_ops: int):
        self.cluster = cluster
        self.pending = list(schedule.events)
        self.n_ops = n_ops
        # (cluster, nid) pairs: over a ShardedCluster a kill lands in one
        # group's Cluster and the matching restart must revive it THERE
        self.killed: List[Tuple[object, int]] = []
        self.timeline: List[dict] = []
        self.phase = "steady"
        self._recoveries = sum(1 for e in schedule.events if e.recovery)
        # runner-private stream (victim picks etc.): drawing here can never
        # shift a SimNet delivery delay, so same-seed runs with different
        # schedules still share the fabric's delivery sequence
        self.rng = random.Random(f"chaosrun:{schedule.seed}")

    def fire_due(self, op_index: int):
        while self.pending and self.pending[0].at * self.n_ops <= op_index:
            ev = self.pending.pop(0)
            detail = self._apply(ev)
            entry = {"op": op_index, "action": ev.action, "detail": detail}
            if ev.group is not None:
                entry["group"] = ev.group
            self.timeline.append(entry)
            if _trace._ACTIVE is not None:
                # annotation only: audit() ignores the "fault" kind, but
                # the exported event stream shows WHEN each fault landed
                # relative to the spans it perturbed
                _trace._ACTIVE.event("fault", -1, 0, action=ev.action,
                                     op=op_index, detail=detail)
            if self.phase == "steady":
                self.phase = "fault"
            if ev.recovery:
                self._recoveries -= 1
                if self._recoveries == 0:
                    self.phase = "recovered"

    def _target(self, ev: FaultEvent):
        """The Cluster an event acts on: over a ShardedCluster, the
        group's own Cluster (ev.group, default shard 0) — every action
        below then runs verbatim against either topology."""
        c = self.cluster
        if hasattr(c, "groups"):
            return c.groups[ev.group if ev.group is not None else 0]
        return c

    def _apply(self, ev: FaultEvent):
        c = self._target(ev)
        if ev.action == "kill_leader":
            nid = c.kill_leader()
            self.killed.append((c, nid))
            return nid
        if ev.action == "restart":
            tc, nid = self.killed.pop() if self.killed else (None, None)
            # mid-op crashes can race a scheduled kill: only revive a node
            # that is actually down — and never a membership-removed id
            if nid is not None and tc.nodes[nid] is None \
                    and nid not in getattr(tc, "removed", ()):
                tc.restart(nid)
            return nid
        if ev.action == "isolate_leader":
            ld = c.elect()
            c.isolate(ld.nid)
            return ld.nid
        if ev.action == "partition_link":
            a, b = divmod(int(ev.arg), c.n)
            c.partition(a, b)
            return [a, b]
        if ev.action == "heal":
            c.heal()
            return None
        if ev.action == "lossy":
            c.set_drop_prob(ev.arg)
            return ev.arg
        if ev.action == "heal_lossy":
            c.set_drop_prob(0.0)
            return None
        if ev.action == "gc_storm":
            return c.force_gc()
        if ev.action == "kill_leader_mid_put":
            fs = faultfs.active()
            ld = c.elect()
            if fs is None:                  # no shim: degrade to a polite kill
                c.crash(ld.nid)
                self.killed.append((c, ld.nid))
                return ld.nid
            # the crash itself fires later, inside whatever put next appends
            # to the leader's value log; the op loop routes it to
            # on_hard_crash so a scheduled 'restart' can still revive it
            fs.arm(0, scope=os.path.join(c._engine_dir(ld.nid), "valuelog"),
                   mode="drop")
            return ld.nid
        if ev.action == "crash_mid_gc":
            fs = faultfs.active()
            if fs is None:
                return c.force_gc()         # degrade to a plain gc_storm
            ld = c.elect()
            # a couple of ops into the run build: inside the build+seal
            # window, before the manifest swap commits the outputs
            fs.arm(int(ev.arg) if ev.arg else 2,
                   scope=os.path.join(c._engine_dir(ld.nid), "run"),
                   mode="torn")
            try:
                c.force_gc()
            except SimulatedCrash as e:
                return self.on_hard_crash(c.hard_crash_from(e), c)
            fs.disarm()                     # GC never touched a run file
            return None
        if ev.action == "crash_mid_adoption":
            fs = faultfs.active()
            ld = c.elect()
            followers = [i for i in range(c.n)
                         if i != ld.nid and c.nodes[i] is not None
                         and i not in c.net.down]
            if fs is None or not followers:
                return None
            fid = followers[0]
            # 'run' also prefixes runs_manifest.json: the crash can land on
            # the adopted run's bytes OR on the manifest swap adopting it
            fs.arm(0, scope=os.path.join(c._engine_dir(fid), "run"),
                   mode="torn")
            try:
                c.force_gc()                # seal a run => a ship record
                for _ in range(600):
                    if not fs.armed:
                        break
                    c.tick()
            except SimulatedCrash as e:
                return self.on_hard_crash(c.hard_crash_from(e), c)
            fs.disarm()                     # nothing shipped in the budget
            return None
        if ev.action == "replace_random_node":
            ld = c.elect()
            cands = [i for i in range(c.n)
                     if c.nodes[i] is not None and i not in c.net.down
                     and i not in getattr(c, "removed", ())
                     and i in ld.voters and i != ld.nid]
            victim = self.rng.choice(cands) if cands else ld.nid
            new = c.replace_node(victim)
            return {"victim": victim, "new": new}
        raise AssertionError(ev.action)

    def on_hard_crash(self, nid, cluster=None):
        """A mid-op SimulatedCrash killed `nid`: remember it so a later
        'restart' event revives it like any scheduled kill.  `nid` may be
        a (group, node) pair from ShardedCluster.hard_crash_from."""
        if nid is None:
            return None
        if isinstance(nid, tuple):
            g, n = nid
            self.killed.append((self.cluster.groups[g], n))
        else:
            self.killed.append((cluster if cluster is not None
                                else self.cluster, nid))
        return nid


# ----------------------------------------------------------------- history
@dataclass
class OpRecord:
    """One completed operation, as the checker sees it.  Writes carry the
    value they wrote (+ the acked raft index); reads carry what came back
    (get: bytes | None, scan: [(key, value)])."""
    op: str                       # 'put' | 'get' | 'scan'
    key: bytes = b""
    value: object = None
    tier: str = LINEARIZABLE
    index: int = 0                # raft index for acked puts
    session: int = -1             # session id for SESSION ops, -1 = none
    lo: bytes = b""               # scan range
    hi: bytes = b""


def check_history(records: Sequence[OpRecord]) -> List[str]:
    """Sequential-history consistency check.  The harness drives ONE
    logical client, so real-time order == program order and
    linearizability degenerates to the exact check "a LINEARIZABLE/LEASE
    read returns the latest acked write"; SESSION ops are held to
    read-your-writes + monotonic-reads per (session, key) — the same
    floor the cluster-side session token enforces by raft index, rebuilt
    here from write sequence numbers so the checker cannot trust the very
    machinery it audits.  Returns human-readable violation strings."""
    violations: List[str] = []
    last: Dict[bytes, Tuple[int, bytes]] = {}      # key -> (seq, value)
    writes: Dict[bytes, Dict[bytes, int]] = {}     # key -> value -> seq
    floor: Dict[Tuple[int, bytes], int] = {}       # (session, key) -> seq

    def note(i, msg):
        violations.append(f"op[{i}] {msg}")

    for i, r in enumerate(records):
        if r.op == "put":
            last[r.key] = (i, r.value)
            writes.setdefault(r.key, {})[r.value] = i
            if r.session >= 0:
                floor[(r.session, r.key)] = i
        elif r.op == "get":
            known = writes.get(r.key, {})
            if r.tier in (LINEARIZABLE, LEASE):
                exp = last.get(r.key, (None, None))[1]
                if r.value != exp:
                    if r.value is not None and r.value not in known:
                        note(i, f"{r.tier} get({r.key!r}) returned a value "
                                "that was never written")
                    elif r.value is None:
                        note(i, f"{r.tier} get({r.key!r}) lost write: "
                                f"latest acked value missing")
                    else:
                        note(i, f"{r.tier} get({r.key!r}) stale read: got "
                                f"write[{known[r.value]}], latest is "
                                f"write[{last[r.key][0]}]")
            else:                                   # SESSION guarantees
                fl = floor.get((r.session, r.key), -1)
                if r.value is None:
                    if fl >= 0:
                        note(i, f"session get({r.key!r}) lost write: "
                                f"session observed write[{fl}] but read "
                                "nothing")
                elif r.value not in known:
                    note(i, f"session get({r.key!r}) returned a value "
                            "that was never written")
                elif known[r.value] < fl:
                    note(i, f"session get({r.key!r}) went backwards: got "
                            f"write[{known[r.value]}] after observing "
                            f"write[{fl}]")
                else:
                    floor[(r.session, r.key)] = known[r.value]
        elif r.op == "scan":
            got = dict(r.value or [])
            if r.tier in (LINEARIZABLE, LEASE):
                # engine scans are inclusive of BOTH bounds ([lo, hi])
                exp = {k: v for k, (_, v) in last.items()
                       if r.lo <= k <= r.hi}
                if got != exp:
                    missing = sorted(set(exp) - set(got))
                    stale = sorted(k for k in got
                                   if k in exp and got[k] != exp[k])
                    extra = sorted(set(got) - set(exp))
                    note(i, f"{r.tier} scan[{r.lo!r},{r.hi!r}) diverged: "
                            f"missing={missing[:3]} stale={stale[:3]} "
                            f"extra={extra[:3]}")
            else:
                for k, v in got.items():
                    known = writes.get(k, {})
                    if v not in known:
                        note(i, f"session scan returned unwritten value "
                                f"for {k!r}")
                    elif known[v] < floor.get((r.session, k), -1):
                        note(i, f"session scan went backwards on {k!r}")
                    else:
                        floor[(r.session, k)] = known[v]
                for (sid, k), fl in floor.items():
                    if sid == r.session and r.lo <= k <= r.hi \
                            and k not in got:
                        note(i, f"session scan lost write: {k!r} observed "
                                f"at write[{fl}] but absent from scan")
        else:
            note(i, f"unknown op {r.op!r}")
    return violations


# ------------------------------------------------------------------ report
@dataclass
class WorkloadReport:
    spec: dict
    chaos: Optional[dict]
    timeline: List[dict]
    hist: Dict[str, LatencyHistogram]                 # label -> latency
    queue_hist: Dict[str, LatencyHistogram]           # arrival -> start
    service_hist: Dict[str, LatencyHistogram]         # start -> completion
    phase_hist: Dict[str, Dict[str, LatencyHistogram]]
    phase_ops: Dict[str, int]
    phase_metrics: Dict[str, dict]                    # summed Metrics.delta
    phase_net: Dict[str, dict]
    violations: List[str]
    refused: Dict[str, int]
    history: List[OpRecord]
    offered_rate: float
    achieved_rate: float
    duration_s: float

    def merged(self, phase: Optional[str] = None,
               contains: Optional[str] = None) -> LatencyHistogram:
        """One histogram over every label matching `contains`, within one
        phase (or overall) — 'what was p99 across the board after the
        failover' is merged('recovered').quantile(0.99)."""
        src = self.phase_hist.get(phase, {}) if phase else self.hist
        out = LatencyHistogram()
        for label, h in src.items():
            if contains is None or contains in label:
                out.merge(h)
        return out

    def summary(self) -> dict:
        """JSON-able digest for BENCH artifacts."""
        return {
            "spec": self.spec,
            "chaos": self.chaos,
            "timeline": self.timeline,
            "offered_rate": round(self.offered_rate, 1),
            "achieved_rate": round(self.achieved_rate, 1),
            "duration_s": round(self.duration_s, 4),
            "violations": self.violations,
            "refused": dict(self.refused),
            "latency_us": {k: h.summary() for k, h in self.hist.items()},
            "queue_us": {k: h.summary()
                         for k, h in self.queue_hist.items()},
            "service_us": {k: h.summary()
                           for k, h in self.service_hist.items()},
            "phases": {p: {"ops": self.phase_ops.get(p, 0),
                           "latency_us": {k: h.summary()
                                          for k, h in hs.items()},
                           "metrics": self.phase_metrics.get(p, {}),
                           "net": self.phase_net.get(p, {})}
                       for p, hs in self.phase_hist.items()},
        }


# ------------------------------------------------------------------ runner
def run_workload(cluster, spec: WorkloadSpec,
                 chaos: Optional[ChaosSchedule] = None,
                 check: bool = True, preload: bool = True,
                 final_scan_check: bool = True) -> WorkloadReport:
    """Drive `cluster` with the open-loop workload, interleaving the chaos
    schedule, and return histograms + checked history.  See the module
    docstring for the latency model."""
    import time as _time

    if spec.virtual_time:
        # the SimNet tick counter is the clock: an op's service time is
        # the ticks it consumed * tick_us, a pure function of the seeds —
        # p99 gates stop depending on how loaded the host CPU is
        def now() -> float:
            return cluster.net.time * spec.tick_us * 1e-6
    else:
        now = _time.perf_counter

    rng = random.Random(f"workload:{spec.seed}")
    arr_rng = random.Random(f"arrivals:{spec.seed}")
    zipf = zipf_key_indices(spec.n_ops, spec.n_keys, spec.zipf_theta,
                            spec.seed)
    tenants = list(spec.tenants)
    weights = [t.weight for t in tenants]
    sessions: Dict[int, Session] = {
        ti: cluster.session() for ti, t in enumerate(tenants)
        if t.tier == SESSION}

    history: List[OpRecord] = []
    wseq = 0
    n_inserted = 0

    def on_crash(e: SimulatedCrash) -> Optional[int]:
        """A SimulatedCrash escaping an op is a node death, not a harness
        error: hard-crash the node whose I/O tripped it and tell the
        chaos runner so a later 'restart' event can revive it."""
        nid = cluster.hard_crash_from(e)
        if runner is not None:
            runner.on_hard_crash(nid)
        return nid

    def do_put(key: bytes, tier: str, sid: int) -> float:
        nonlocal wseq
        val = _value(key, wseq, spec.vsize)
        wseq += 1
        t0 = now()
        try:
            if sid >= 0:
                idx = sessions[sid].put(key, val)
            else:
                idx = cluster.put(key, val)
        except SimulatedCrash as e:
            on_crash(e)
            # ack ambiguity: the crash may sit between quorum commit and
            # the client ack.  Ask the surviving majority what it kept and
            # record the write only if it landed — with no session floor,
            # because the session never saw an ack.
            if cluster.get(key, LINEARIZABLE) == val:
                history.append(OpRecord("put", key, val, tier))
            return now() - t0
        history.append(OpRecord("put", key, val, tier, index=idx,
                                session=sid))
        return now() - t0

    # ---- preload: the keyspace every read/scan starts from -------------
    if preload:
        items = []
        for i in range(spec.n_keys):
            val = _value(_key(i), wseq, spec.vsize)
            history.append(OpRecord("put", _key(i), val))
            items.append((_key(i), val))
            wseq += 1
        cluster.put_many(items)

    # ---- arrival schedule (Poisson) ------------------------------------
    arrivals = []
    t = 0.0
    for _ in range(spec.n_ops):
        t += arr_rng.expovariate(spec.rate)
        arrivals.append(t)

    runner = _ChaosRunner(cluster, chaos, spec.n_ops) if chaos else None
    hist: Dict[str, LatencyHistogram] = {}
    qhist: Dict[str, LatencyHistogram] = {}
    shist: Dict[str, LatencyHistogram] = {}
    phase_hist: Dict[str, Dict[str, LatencyHistogram]] = {}
    phase_ops: Dict[str, int] = {}
    refused: Dict[str, int] = {}
    samples: List[Tuple[int, str, float]] = []   # (op idx, label, service)
    phase_of_op: List[str] = []
    phase_snaps = {"steady": [m.snapshot() for m in cluster.metrics]}
    phase_net_base = {"steady": (cluster.net.sent_msgs,
                                 cluster.net.dropped_msgs)}
    phase_metrics: Dict[str, dict] = {}
    phase_net: Dict[str, dict] = {}

    def close_phase(name):
        """Cluster-summed Metrics movement + net movement for `name`."""
        base = phase_snaps.pop(name, None)
        if base is None:
            return
        deltas = [m.delta(s) for m, s in zip(cluster.metrics, base)]
        agg = {"fsyncs": 0, "read_quorum_rounds": 0, "gc_bytes": 0,
               "ship_bytes": 0, "follower_serves": 0, "session_stalls": 0}
        for d in deltas:
            agg["fsyncs"] += d["fsyncs"]
            agg["read_quorum_rounds"] += d["read_quorum_rounds"]
            agg["follower_serves"] += d["follower_serves"]
            agg["session_stalls"] += d["session_stalls"]
            agg["gc_bytes"] += d["write_bytes"].get("gc_sorted", 0) + \
                d["write_bytes"].get("gc_level_merge", 0)
            agg["ship_bytes"] += sum(d["ship_bytes"].values())
        phase_metrics[name] = agg
        sm, dm = phase_net_base.pop(name)
        phase_net[name] = {"sent_msgs": cluster.net.sent_msgs - sm,
                           "dropped_msgs": cluster.net.dropped_msgs - dm}

    # ---- the op loop ---------------------------------------------------
    cur_phase = "steady"
    for i in range(spec.n_ops):
        if runner is not None:
            runner.fire_due(i)
            if runner.phase != cur_phase:
                close_phase(cur_phase)
                cur_phase = runner.phase
                phase_snaps[cur_phase] = [m.snapshot()
                                          for m in cluster.metrics]
                phase_net_base[cur_phase] = (cluster.net.sent_msgs,
                                             cluster.net.dropped_msgs)
        ti = rng.choices(range(len(tenants)), weights=weights)[0]
        ten = tenants[ti]
        mix = ten.mix_spec()
        sid = ti if ten.tier == SESSION else -1
        ki = int(zipf[i])
        r = rng.random()
        label_base = f"{ten.name}:" if len(tenants) > 1 else ""
        if r < mix["write"]:
            if mix.get("insert"):
                ki = spec.n_keys + n_inserted
                n_inserted += 1
            label = f"{label_base}put"
            dt = do_put(_key(ki), ten.tier, sid)
        elif r < mix["write"] + mix["scan"]:
            label = f"{label_base}scan:{ten.tier}"
            lo = _key(ki)
            hi = _key(ki + spec.scan_span)
            t0 = now()
            try:
                if sid >= 0:
                    got = sessions[sid].scan(lo, hi)
                else:
                    got = cluster.scan(lo, hi, ten.tier)
                history.append(OpRecord("scan", value=got, tier=ten.tier,
                                        session=sid, lo=lo, hi=hi))
            except StaleReadError:
                refused[label] = refused.get(label, 0) + 1
            except SimulatedCrash as e:
                on_crash(e)          # unacked read: nothing to record
            dt = now() - t0
        elif r < mix["write"] + mix["scan"] + mix["rmw"]:
            label = f"{label_base}rmw:{ten.tier}"
            t0 = now()
            try:
                if sid >= 0:
                    got = sessions[sid].get(_key(ki))
                else:
                    got = cluster.get(_key(ki), ten.tier)
                history.append(OpRecord("get", _key(ki), got, ten.tier,
                                        session=sid))
            except StaleReadError:
                refused[label] = refused.get(label, 0) + 1
            except SimulatedCrash as e:
                on_crash(e)
            do_put(_key(ki), ten.tier, sid)
            dt = now() - t0
        else:
            label = f"{label_base}get:{ten.tier}"
            t0 = now()
            try:
                if sid >= 0:
                    got = sessions[sid].get(_key(ki))
                else:
                    got = cluster.get(_key(ki), ten.tier)
                history.append(OpRecord("get", _key(ki), got, ten.tier,
                                        session=sid))
            except StaleReadError:
                refused[label] = refused.get(label, 0) + 1
            except SimulatedCrash as e:
                on_crash(e)
            dt = now() - t0
        samples.append((i, label, dt))
        phase_of_op.append(cur_phase)
    if runner is not None:
        runner.fire_due(spec.n_ops)      # fire any events at the tail
    close_phase(cur_phase)

    # ---- open-loop reconstruction --------------------------------------
    completion = 0.0
    for (i, label, service), phase in zip(samples, phase_of_op):
        start = max(arrivals[i], completion)
        completion = start + service
        lat_us = (completion - arrivals[i]) * 1e6
        hist.setdefault(label, LatencyHistogram()).record(lat_us)
        qhist.setdefault(label, LatencyHistogram()).record(
            (start - arrivals[i]) * 1e6)
        shist.setdefault(label, LatencyHistogram()).record(service * 1e6)
        phase_hist.setdefault(phase, {}).setdefault(
            label, LatencyHistogram()).record(lat_us)
        phase_ops[phase] = phase_ops.get(phase, 0) + 1
    duration = completion if samples else 0.0

    # ---- verification --------------------------------------------------
    violations: List[str] = []
    if check:
        if final_scan_check:
            fs = faultfs.active()
            if fs is not None and fs.armed:
                fs.disarm()          # an armed-but-unfired mid-op fault
            # end-state audit: one linearizable scan of the whole keyspace
            # must equal the checker's expected map — a write lost during
            # chaos that no per-op read happened to cover still shows here
            got = cluster.scan(_key(0), _key(10 ** 7), LINEARIZABLE)
            history.append(OpRecord("scan", value=got, tier=LINEARIZABLE,
                                    lo=_key(0), hi=_key(10 ** 7)))
        violations = check_history(history)

    return WorkloadReport(
        spec=spec.record(),
        chaos=chaos.record() if chaos else None,
        timeline=runner.timeline if runner else [],
        hist=hist, queue_hist=qhist, service_hist=shist,
        phase_hist=phase_hist, phase_ops=phase_ops,
        phase_metrics=phase_metrics, phase_net=phase_net,
        violations=violations, refused=refused, history=history,
        offered_rate=spec.rate,
        achieved_rate=(len(samples) / duration) if duration else 0.0,
        duration_s=duration)


# ------------------------------------------------------- crash-point sweeps
# The seeded probe workload every crash-point sweep records and replays:
# small on purpose — the sweep domain is EVERY numbered I/O op the run
# issues, so op count, not op variety, is the knob.
CRASHPOINT_OPS = 18
CRASHPOINT_KEYS = 6
CRASHPOINT_VSIZE = 96
LIVENESS_KEY = _key(10 ** 6)     # outside every audit scan range


def _crashpoint_put_stream(n_ops: int) -> Iterator[Tuple[int, bytes, bytes]]:
    """The deterministic acked-write stream: op j overwrites key j%K with
    a value stamped by j, so 'latest value per key' is a pure function of
    how far the run got before the crash."""
    for j in range(n_ops):
        key = _key(j % CRASHPOINT_KEYS)
        yield j, key, _value(key, j, CRASHPOINT_VSIZE)


def _audit_cluster(cluster) -> List[str]:
    """Structural durability audit, beyond what client reads can see:
    raft log shape (offsets paired with entries, non-decreasing terms,
    commit inside the log), and manifest/run-set agreement (every
    manifest run exists on disk, is at least as long as its index says,
    and the manifest boundary covers the newest run)."""
    probs: List[str] = []
    for i, nd in enumerate(cluster.nodes):
        if nd is None:
            continue
        if len(nd.offsets) != len(nd.entries):
            probs.append(f"node{i}: {len(nd.offsets)} offsets for "
                         f"{len(nd.entries)} log entries")
        terms = [e.term for e in nd.entries]
        if any(a > b for a, b in zip(terms, terms[1:])):
            probs.append(f"node{i}: raft log terms decrease")
        if nd.commit_index > nd.snap_index + len(nd.entries):
            probs.append(f"node{i}: commit_index {nd.commit_index} past "
                         f"log end {nd.snap_index + len(nd.entries)}")
        lvl = getattr(cluster.engines[i], "leveled", None)
        if lvl is None:
            continue
        for r in lvl.runs:
            if not os.path.exists(r.path):
                probs.append(f"node{i}: manifest names missing run file "
                             f"{os.path.basename(r.path)}")
                continue
            need = max((off + ln for off, ln in r.index.values()), default=0)
            size = os.path.getsize(r.path)
            if size < need:
                probs.append(f"node{i}: run {os.path.basename(r.path)} is "
                             f"{size}B, its index needs {need}B")
        if lvl.runs:
            newest = max(r.last_index for r in lvl.runs)
            if lvl.boundary[0] < newest:
                probs.append(f"node{i}: manifest boundary {lvl.boundary[0]}"
                             f" behind newest run {newest}")
    return probs


def _close_engines(cluster):
    if cluster is not None:
        for e in cluster.engines:
            if e is not None:
                e.close()


def _verify_recovery(target, acked) -> Tuple[List[str], List[str]]:
    """acked-write-loss check (check_history over the acked stream + one
    linearizable full-range scan) + the structural audit."""
    history = [OpRecord("put", k, v) for k, v in acked]
    lo, hi = _key(0), _key(CRASHPOINT_KEYS + 10)
    got = target.scan(lo, hi, LINEARIZABLE)
    history.append(OpRecord("scan", value=got, tier=LINEARIZABLE,
                            lo=lo, hi=hi))
    return check_history(history), _audit_cluster(target)


def run_crashpoint(workdir: str, seed: int = 0,
                   crash_index: Optional[int] = None, mode: str = "drop",
                   n_ops: int = CRASHPOINT_OPS, engine: str = "nezha",
                   gc_every: int = 6) -> dict:
    """One crash-point probe: run the seeded single-node workload with a
    FaultFS installed, kill -9 the node at I/O op `crash_index` (None =
    record run: never crash, just count the ops — the sweep domain),
    recover from the durable view, and audit.

    The gate is result["recovered_ok"]: no acked write lost (check_history
    over the acked stream + a final linearizable scan) and a clean
    structural audit.  Any sweep failure reproduces from
    run_crashpoint(dir, seed=SEED, crash_index=K, mode=MODE) alone."""
    from repro.core.cluster import Cluster
    from repro.core.faultfs import FaultFS, install, uninstall

    fs = FaultFS(seed=seed)
    install(fs)
    cluster = rec = None
    acked: List[Tuple[bytes, bytes]] = []
    inflight = crash = None
    try:
        # armed BEFORE construction: cluster bring-up I/O is part of the
        # numbered op stream, so crash indices align with the record run
        if crash_index is not None:
            fs.arm(crash_index, scope=os.path.abspath(workdir) + os.sep,
                   mode=mode)
        try:
            cluster = Cluster(n=1, engine=engine, workdir=workdir,
                              seed=seed, sync=True,
                              engine_kwargs={"gc_threshold": 2048}
                              if engine == "nezha" else None)
            cluster.elect()
            for j, key, val in _crashpoint_put_stream(n_ops):
                inflight = (key, val)
                cluster.put(key, val)
                acked.append((key, val))
                inflight = None
                if (j + 1) % gc_every == 0:
                    cluster.force_gc()
        except SimulatedCrash as e:
            crash = e
        result = {"seed": seed, "mode": mode, "crash_index": crash_index,
                  "ops": fs.op_count, "acked": len(acked),
                  "crashed": crash is not None, "crash": None}
        if crash is None:
            fs.disarm()
            target = cluster
        else:
            result["crash"] = {"op_index": crash.op_index,
                               "kind": crash.kind,
                               "path": os.path.basename(crash.path)}
            # kill -9: abandon the cluster un-closed, settle the directory
            # to its durable view, then boot a recovery cluster from it
            fs.materialize(os.path.abspath(workdir) + os.sep)
            rec = Cluster(n=1, engine=engine, workdir=workdir,
                          seed=seed + 1, sync=True,
                          engine_kwargs={"gc_threshold": 2048}
                          if engine == "nezha" else None,
                          recover=True)
            rec.elect()
            # liveness probe; also the new-term entry Raft needs before it
            # may commit any surviving old-term tail
            rec.put(LIVENESS_KEY, b"alive")
            if inflight is not None and \
                    rec.get(inflight[0], LINEARIZABLE) == inflight[1]:
                # ack ambiguity: the in-flight write counts as acked iff
                # the recovered node kept it
                acked.append(inflight)
            target = rec
        result["violations"], result["audit"] = _verify_recovery(target,
                                                                 acked)
        result["faults"] = fs.counters()
        result["recovered_ok"] = not result["violations"] and \
            not result["audit"]
        return result
    finally:
        uninstall()
        # the crashed cluster's handles were closed by materialize();
        # whichever cluster survived closes politely
        _close_engines(rec)
        if crash is None:
            _close_engines(cluster)


def run_full_restart(workdir: str, seed: int = 0, crash_index: int = 60,
                     mode: str = "torn", n: int = 3, engine: str = "nezha",
                     n_ops: int = 24) -> dict:
    """Fleet power loss: kill ALL n nodes at a (possibly torn) I/O point,
    restart every node from its durable view, and require (a) no acked
    write lost and (b) byte-equal engine scans on every node once the
    applies settle."""
    from repro.core.cluster import Cluster
    from repro.core.faultfs import FaultFS, install, uninstall

    fs = FaultFS(seed=seed)
    install(fs)
    cluster = rec = None
    try:
        fs.arm(crash_index, scope=os.path.abspath(workdir) + os.sep,
               mode=mode)
        acked: List[Tuple[bytes, bytes]] = []
        inflight = crash = None
        try:
            cluster = Cluster(n=n, engine=engine, workdir=workdir,
                              seed=seed, sync=True,
                              engine_kwargs={"gc_threshold": 4096})
            cluster.elect()
            for j, key, val in _crashpoint_put_stream(n_ops):
                inflight = (key, val)
                cluster.put(key, val)
                acked.append((key, val))
                inflight = None
                if (j + 1) % 8 == 0:
                    cluster.force_gc()
                    cluster.drain_shipping(2000)
        except SimulatedCrash as e:
            crash = e
        if crash is None:
            fs.disarm()
        # every node dies at the same instant: one materialize over the
        # whole workdir, no goodbye flush anywhere
        changed = fs.materialize(os.path.abspath(workdir) + os.sep)
        rec = Cluster(n=n, engine=engine, workdir=workdir, seed=seed + 1,
                      sync=True, engine_kwargs={"gc_threshold": 4096},
                      recover=True)
        rec.elect()
        rec.put(LIVENESS_KEY, b"alive")
        if inflight is not None and \
                rec.get(inflight[0], LINEARIZABLE) == inflight[1]:
            acked.append(inflight)
        for _ in range(6000):               # settle applies on every node
            ld = rec.leader()
            if ld is not None and all(
                    nd is not None and nd.last_applied >= ld.commit_index
                    for nd in rec.nodes):
                break
            rec.tick()
        violations, audit = _verify_recovery(rec, acked)
        lo, hi = _key(0), _key(CRASHPOINT_KEYS + 10)
        scans = [e.scan(lo, hi) for e in rec.engines if e is not None]
        converged = bool(scans) and all(s == scans[0] for s in scans[1:])
        return {"seed": seed, "mode": mode, "crash_index": crash_index,
                "crashed": crash is not None,
                "crash": None if crash is None else
                {"op_index": crash.op_index, "kind": crash.kind,
                 "path": os.path.basename(crash.path)},
                "acked": len(acked), "files_settled": changed,
                "violations": violations, "audit": audit,
                "converged": converged, "faults": fs.counters(),
                "recovered_ok": converged and not violations and not audit}
    finally:
        uninstall()
        _close_engines(rec)


def run_membership_crashpoint(workdir: str, seed: int = 0,
                              crash_index: Optional[int] = None,
                              mode: str = "torn", n: int = 3,
                              engine: str = "nezha",
                              n_ops: int = 12) -> dict:
    """Crash-point probe of the config-change commit window: run the
    scripted self-healing cycle (puts -> gc -> add learner -> promote ->
    remove a founding voter -> more puts) with a FaultFS installed, kill
    the WHOLE fleet at I/O op `crash_index` (None = record run: never
    crash, report the window as result["member_window"]), recover from
    the durable views, and audit.

    Beyond run_full_restart's gates (no acked write lost, byte-equal
    scans), this one proves the two membership-safety clauses across the
    crash boundary: every live member converges on ONE committed config
    (no two disjoint quorums), and merging the leadership histories of
    the pre-crash and post-crash incarnations never shows two leaders
    for one term."""
    from repro.core.cluster import Cluster
    from repro.core.faultfs import FaultFS, install, uninstall

    fs = FaultFS(seed=seed)
    install(fs)
    cluster = rec = None
    acked: List[Tuple[bytes, bytes]] = []
    inflight = crash = None
    window = [0, 0]
    histories: List[Tuple[int, int]] = []
    kw = dict(engine=engine, workdir=workdir, sync=True,
              engine_kwargs={"gc_threshold": 4096})
    try:
        if crash_index is not None:
            fs.arm(crash_index, scope=os.path.abspath(workdir) + os.sep,
                   mode=mode)
        try:
            cluster = Cluster(n=n, seed=seed, **kw)
            cluster.elect()
            for j, key, val in _crashpoint_put_stream(n_ops):
                inflight = (key, val)
                cluster.put(key, val)
                acked.append((key, val))
                inflight = None
            cluster.force_gc()          # sealed runs => catch-up has a
            cluster.drain_shipping(2000)   # snapshot + run-ship path
            window[0] = fs.op_count
            new = cluster.add_node()
            cluster.wait_promoted(new)
            cluster.remove_node(1)      # retire a founding voter
            window[1] = fs.op_count
            for j, key, val in _crashpoint_put_stream(6):
                val = _value(key, 100 + j, CRASHPOINT_VSIZE)
                inflight = (key, val)
                cluster.put(key, val)
                acked.append((key, val))
                inflight = None
        except SimulatedCrash as e:
            crash = e
        if crash is None:
            fs.disarm()
        # pre-crash leadership evidence survives in the abandoned
        # in-memory nodes; collect it before booting the recovery fleet
        for nd in (cluster.nodes if cluster is not None else []):
            if nd is not None:
                histories.extend(nd.leadership_history)
        changed = fs.materialize(os.path.abspath(workdir) + os.sep)
        # recover=True sizes the fleet and the removed set from the
        # cluster manifest; a node whose meta never made it to disk is
        # rebuilt from its recorded construction config
        rec = Cluster(n=n, seed=seed + 1, recover=True, **kw)
        rec.elect()
        rec.put(LIVENESS_KEY, b"alive")
        if inflight is not None and \
                rec.get(inflight[0], LINEARIZABLE) == inflight[1]:
            acked.append(inflight)
        # settle: every node the leader's config counts as a member must
        # apply up to the leader's commit AND agree on the config — a
        # stale non-member (e.g. the removed voter whose config entry
        # never reached it) is ignored: it can neither vote nor win
        for _ in range(12000):
            ld = rec.leader()
            if ld is not None:
                members = set(ld.voters) | set(ld.learners)
                live = [(i, nd) for i, nd in enumerate(rec.nodes)
                        if nd is not None and i in members]
                if live and all(nd.last_applied >= ld.commit_index and
                                nd.voters == ld.voters
                                for _, nd in live):
                    break
            rec.tick()
        ld = rec.leader()
        for nd in rec.nodes:
            if nd is not None:
                histories.extend(nd.leadership_history)
        # election safety across the crash: one leader per term, ever
        by_term: Dict[int, int] = {}
        double: List[Tuple[int, List[int]]] = []
        for term, nid in histories:
            if term in by_term and by_term[term] != nid:
                double.append((term, sorted((by_term[term], nid))))
            by_term.setdefault(term, nid)
        # one-quorum check: the members agree on one committed config
        members = set(ld.voters) | set(ld.learners) if ld else set()
        configs = {(nd.config_index, tuple(sorted(nd.voters)))
                   for i, nd in enumerate(rec.nodes)
                   if nd is not None and i in members}
        one_config = len(configs) == 1
        violations, audit = _verify_recovery(rec, acked)
        lo, hi = _key(0), _key(CRASHPOINT_KEYS + 10)
        scans = [rec.engines[i].scan(lo, hi)
                 for i in (sorted(ld.voters) if ld else [])
                 if i < len(rec.engines) and rec.engines[i] is not None]
        converged = bool(scans) and all(s == scans[0] for s in scans[1:])
        return {"seed": seed, "mode": mode, "crash_index": crash_index,
                "ops": fs.op_count, "member_window": tuple(window),
                "crashed": crash is not None,
                "crash": None if crash is None else
                {"op_index": crash.op_index, "kind": crash.kind,
                 "path": os.path.basename(crash.path)},
                "acked": len(acked), "files_settled": changed,
                "violations": violations, "audit": audit,
                "converged": converged, "one_config": one_config,
                "double_leaders": double,
                "voters": sorted(ld.voters) if ld else [],
                "faults": fs.counters(),
                "recovered_ok": converged and one_config and not double
                and not violations and not audit}
    finally:
        uninstall()
        _close_engines(rec)
