"""Storage modules of Nezha's adaptive storage management (paper §III-C).

  * StorageModule   — Active / New: a ValueLog (raft entries incl. values,
                      appended once) + a MiniLSM index of key -> offset.
  * SortedStore     — one immutable key-sorted ValueLog + hash index +
                      per-run bloom filter + (last_index, last_term) Raft
                      boundary.  Supports crash-resume (last key written =
                      interrupt point, paper §III-E).
  * SortedRun       — a SortedStore living inside the leveled hierarchy
                      (run id + level instead of a generation number).
  * LeveledStore    — the leveled-GC run hierarchy: GC of the active
                      segment seals a new L0 run (bounded work per cycle);
                      a level holding `fanout` runs merges into one run on
                      the next level.  Membership + Raft boundaries live in
                      an atomically-replaced manifest, so crash recovery
                      and InstallSnapshot semantics hold across any number
                      of runs.
  * kway_merge_newest_wins — streaming heap merge over key-ascending
                      sources with newest-wins dedup (the scan read path).
"""
from __future__ import annotations

import heapq
import json
import os
import struct
from bisect import bisect_left, bisect_right
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.cache import BlockCache, BloomFilter, next_namespace
from repro.core.faultfs import (fs_fsync, fs_fsync_path, fs_open, fs_remove,
                                write_json_atomic)
from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog, _HDR

_OFF = struct.Struct("<Q")


def pack_offset(off: int) -> bytes:
    return _OFF.pack(off)


def unpack_offset(b: bytes) -> int:
    return _OFF.unpack(b)[0]


class StorageModule:
    """ValueLog + lightweight key->offset index (the paper's 'RocksDB')."""

    def __init__(self, dirpath: str, metrics: Metrics, tag: str,
                 sync: bool = False, group_commit: bool = False,
                 cache: Optional[BlockCache] = None):
        self.dir = dirpath
        self.tag = tag
        self.metrics = metrics
        self.vlog = ValueLog(os.path.join(dirpath, f"valuelog_{tag}.log"),
                             metrics, category="valuelog", sync=sync,
                             group_commit=group_commit, cache=cache)
        self.db = MiniLSM(os.path.join(dirpath, f"db_{tag}"), metrics,
                          wal=True, name=f"db_{tag}", sync=sync,
                          group_commit=group_commit, cache=cache)

    def apply(self, entry: LogEntry, offset: int):
        """State-machine apply: store ONLY the offset (Algorithm 1 line 7)."""
        self.db.put(entry.key, pack_offset(offset))

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Group apply: all offset records become one buffered WAL write."""
        self.db.put_batch([(e.key, pack_offset(off)) for e, off in pairs])

    def sync_now(self):
        """Commit-window boundary: one fsync each for vlog + index WAL."""
        self.vlog.sync_now()
        self.db.sync_wal()

    def get_offset(self, key: bytes) -> Optional[int]:
        v = self.db.get(key)
        return None if v is None else unpack_offset(v)

    def read_value(self, offset: int) -> bytes:
        return self.vlog.read_value_at(offset)

    def get(self, key: bytes) -> Optional[bytes]:
        off = self.get_offset(key)
        return None if off is None else self.read_value(off)

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        """Range scan: sorted key->offset pairs then scattered value reads."""
        return list(self.scan_iter(lo, hi))

    def scan_iter(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        """Key-ascending stream; values are fetched lazily per item so a
        k-way merge that drops a superseded key pays for it only once."""
        for k, v in self.db.scan(lo, hi):
            yield k, self.read_value(unpack_offset(v))

    def sorted_items(self) -> Iterator[Tuple[bytes, int]]:
        for k, v in self.db.iterate_all():
            yield k, unpack_offset(v)

    def destroy(self):
        self.vlog.delete()
        self.db.destroy()

    def close(self):
        self.vlog.close()
        self.db.close()


class SortedStore:
    """One immutable key-ordered ValueLog + hash index + bloom filter +
    snapshot metadata.  A range scan costs one seek + sequential bytes."""

    # stream-decode chunk size: bounds memory on the recovery/GC paths
    CHUNK_BYTES = 1 << 20

    def __init__(self, dirpath: str, metrics: Metrics, gen: int = 0,
                 cache: Optional[BlockCache] = None,
                 name: Optional[str] = None):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics
        self.gen = gen
        self.cache = cache
        self._cache_ns = next_namespace()
        stem = name if name is not None else f"sorted_{gen:04d}"
        self.path = os.path.join(dirpath, f"{stem}.log")
        self.meta_path = os.path.join(dirpath, f"{stem}.meta")
        self.index: Dict[bytes, Tuple[int, int]] = {}  # key -> (off, len)
        self.keys: List[bytes] = []                    # sorted
        self.bloom: Optional[BloomFilter] = None       # point-get gate
        self.last_index = 0
        self.last_term = 0
        self._complete = False
        self._rf = None   # persistent read handle, opened lazily

    def _reset_read_state(self):
        """File bytes changed (build/install/destroy): drop handle + cache."""
        if self._rf is not None:
            self._rf.close()
            self._rf = None
        if self.cache is not None:
            self.cache.invalidate(self._cache_ns)
            self._cache_ns = next_namespace()

    def _stream_records(self, category: Optional[str] = None
                        ) -> Iterator[Tuple[int, LogEntry]]:
        """Chunked sequential decode of (offset, entry); never materializes
        the whole file.  Bytes consumed are accounted to `category` exactly
        as the old whole-file read was (same totals, chunked ops)."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            buf = b""
            base = 0          # file offset of buf[0]
            while True:
                chunk = f.read(self.CHUNK_BYTES)
                if chunk and category is not None:
                    self.metrics.on_read(category, len(chunk))
                if not chunk and not buf:
                    return
                buf += chunk
                off = 0
                while off + _HDR.size <= len(buf):
                    _, _, _, _, klen, vlen = _HDR.unpack_from(buf, off)
                    rlen = _HDR.size + klen + vlen
                    if off + rlen > len(buf):
                        break
                    entry, _ = LogEntry.decode(buf, off)
                    yield base + off, entry
                    off += rlen
                base += off
                buf = buf[off:]
                if not chunk:
                    return  # EOF; leftover buf is a torn tail, tolerated

    # --------------------------------------------------------------- build
    def build(self, items: Iterator[Tuple[bytes, LogEntry]],
              last_index: int, last_term: int):
        """One-shot build: write key-ascending entries and seal."""
        self._reset_read_state()
        fs_open(self.path, "wb").close()    # fresh file
        self.index.clear()
        self.keys = []
        self.append_items(items, "gc_sorted")
        self.seal(last_index, last_term)

    def append_items(self, items, category: str) -> int:
        """Incremental build: append encoded entries (key-ascending),
        maintaining index/keys.  Returns bytes written.  Shared by the GC
        flush and level-merge paths so framing + accounting can't drift."""
        written = 0
        with fs_open(self.path, "ab") as f:
            off = f.tell()
            for key, entry in items:
                data = entry.encode()
                f.write(data)
                self.metrics.on_write(category, len(data))
                self.index[key] = (off, len(data))
                self.keys.append(key)
                off += len(data)
                written += len(data)
        return written

    def seal(self, last_index: int, last_term: int):
        """Mark the run complete: Raft boundary + bloom + durable meta.
        The data file is fsynced BEFORE the meta commits — a meta that says
        `complete` over a torn data file would survive kill -9 otherwise."""
        self.last_index = last_index
        self.last_term = last_term
        self.bloom = BloomFilter.from_keys(self.keys)
        self._complete = True
        if os.path.exists(self.path):
            fs_fsync_path(self.path)
        write_json_atomic(self.meta_path,
                          {"last_index": last_index, "last_term": last_term,
                           "complete": True})
        self.metrics.on_write("gc_meta", 64)

    def last_key_on_disk(self) -> Optional[bytes]:
        """Crash-resume support: stream the partial file for its last key
        (chunked — the old implementation slurped the whole file)."""
        last = None
        try:
            for _, entry in self._stream_records("gc_resume_scan"):
                last = entry.key
        except Exception:
            pass  # torn/corrupt tail: resume from the last good key
        return last

    def load_partial(self) -> Optional[bytes]:
        """Crash-resume: rebuild index/keys from a partially-built run with
        the bounded-memory stream, cutting off any torn tail record so the
        resumed build appends at a clean boundary.  Returns the last
        complete key (the interrupt point), or None if nothing landed."""
        self.index.clear()
        self.keys = []
        last = None
        valid_end = 0
        try:
            for off, entry in self._stream_records("gc_resume_scan"):
                rlen = _HDR.size + len(entry.key) + len(entry.value)
                self.index[entry.key] = (off, rlen)
                self.keys.append(entry.key)
                last = entry.key
                valid_end = off + rlen
        except Exception:
            pass  # corrupt tail: everything before it is still good
        if os.path.exists(self.path) and \
                os.path.getsize(self.path) > valid_end:
            with fs_open(self.path, "r+b") as f:
                f.truncate(valid_end)
        self._started = last is not None
        return last

    def load(self) -> bool:
        """Recovery: reload index from the sorted file + meta, streaming in
        CHUNK_BYTES pieces; byte totals match the old whole-file read."""
        if not os.path.exists(self.meta_path):
            return False
        if not os.path.exists(self.path):
            # meta without data = real loss; fail loudly (silently loading
            # an empty index would make every GC'd key vanish)
            raise FileNotFoundError(self.path)
        with open(self.meta_path) as f:
            meta = json.load(f)
        self.last_index = meta["last_index"]
        self.last_term = meta["last_term"]
        self.index.clear()
        self.keys = []
        for off, entry in self._stream_records("recover_sorted"):
            self.index[entry.key] = (
                off, _HDR.size + len(entry.key) + len(entry.value))
            self.keys.append(entry.key)
        self.bloom = BloomFilter.from_keys(self.keys)
        self._complete = True
        self._reset_read_state()
        return True

    # --------------------------------------------------------------- reads
    def get(self, key: bytes) -> Optional[bytes]:
        if self.bloom is not None and key not in self.bloom:
            self.metrics.on_bloom_skip()   # negative: zero I/O, zero probes
            return None
        loc = self.index.get(key)          # hash index: direct lookup
        if loc is None:
            return None
        if self.cache is not None:
            buf = self.cache.get(self._cache_ns, loc[0])
            if buf is not None:
                self.metrics.on_cache_hit("sorted_point")
                entry, _ = LogEntry.decode(buf, 0)
                return entry.value
        if self._rf is None:
            self._rf = open(self.path, "rb")
        self._rf.seek(loc[0])
        buf = self._rf.read(loc[1])
        self.metrics.on_read("sorted_point", len(buf))
        if self.cache is not None:
            self.cache.put(self._cache_ns, loc[0], buf)
        entry, _ = LogEntry.decode(buf, 0)
        return entry.value

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        return list(self.scan_iter(lo, hi))

    def scan_iter(self, lo: bytes, hi: bytes) -> Iterator[Tuple[bytes, bytes]]:
        """ONE random seek to the start key, then a sequential CHUNK_BYTES
        stream — the whole range is never materialized.  Reads go through
        the persistent handle (re-seeking per chunk, so interleaved point
        gets on the same handle stay safe)."""
        i = bisect_left(self.keys, lo)
        j = bisect_right(self.keys, hi)
        if i >= j:
            return
        pos = self.index[self.keys[i]][0]
        end_off, end_len = self.index[self.keys[j - 1]]
        remaining = end_off + end_len - pos
        if self._rf is None:
            self._rf = open(self.path, "rb")
        buf = b""
        while remaining > 0:
            self._rf.seek(pos)
            chunk = self._rf.read(min(self.CHUNK_BYTES, remaining))
            if not chunk:
                break
            pos += len(chunk)
            remaining -= len(chunk)
            self.metrics.on_read("sorted_range", len(chunk))
            buf += chunk
            off = 0
            while off + _HDR.size <= len(buf):
                _, _, _, _, klen, vlen = _HDR.unpack_from(buf, off)
                rlen = _HDR.size + klen + vlen
                if off + rlen > len(buf):
                    break
                entry, _ = LogEntry.decode(buf, off)
                yield entry.key, entry.value
                off += rlen
            buf = buf[off:]

    def items(self) -> Iterator[Tuple[bytes, LogEntry]]:
        for _, entry in self._stream_records("gc_merge_read"):
            yield entry.key, entry

    def snapshot_payload(self) -> bytes:
        """Whole sorted file — Raft InstallSnapshot payload for catch-up."""
        with open(self.path, "rb") as f:
            data = f.read()
        self.metrics.on_ship("snapshot", len(data))
        return data

    def install_payload(self, payload: bytes, last_index: int,
                        last_term: int, category: str = "snapshot_install"):
        self._reset_read_state()
        with fs_open(self.path, "wb") as f:
            f.write(payload)
            fs_fsync(f)   # data durable before the meta declares `complete`
        self.metrics.on_write(category, len(payload))
        write_json_atomic(self.meta_path,
                          {"last_index": last_index, "last_term": last_term,
                           "complete": True})
        self.load()

    def data_bytes(self) -> int:
        return sum(length for _, length in self.index.values())

    def close(self):
        if self._rf is not None:
            self._rf.close()
            self._rf = None

    def destroy(self):
        self._reset_read_state()
        for p in (self.path, self.meta_path):
            fs_remove(p)


class SortedRun(SortedStore):
    """A SortedStore inside the leveled hierarchy: addressed by a run id
    (never reused, reserved in the manifest before the file is born) and a
    level.  The (last_index, last_term) boundary is the Raft log position
    this run's data is complete up to."""

    def __init__(self, dirpath: str, metrics: Metrics, rid: int,
                 level: int = 0, cache: Optional[BlockCache] = None):
        super().__init__(dirpath, metrics, cache=cache,
                         name=f"run_{rid:06d}")
        self.rid = rid
        self.level = level


def kway_merge_newest_wins(sources) -> Iterator[Tuple[bytes, object]]:
    """Streaming heap merge of key-ascending (key, payload) iterators.

    `sources` must be ordered newest-first; equal keys pop in source order
    (the heap tuple is (key, rank, ...)), so the newest version is yielded
    and older ones are skipped.  Wall-clock and memory are O(k) per item —
    nothing is materialized."""
    heap = []
    for rank, it in enumerate(sources):
        first = next(it, None)
        if first is not None:
            heap.append((first[0], rank, first[1], it))
    heapq.heapify(heap)
    last_key = None
    while heap:
        if len(heap) == 1:
            # fast path: one live source left (each source is already
            # deduped + ascending) — drain it with zero heap traffic
            key, _, payload, it = heap[0]
            if key != last_key:
                yield key, payload
            yield from it
            return
        key, rank, payload, it = heapq.heappop(heap)
        if key != last_key:
            yield key, payload
            last_key = key
        nxt = next(it, None)
        if nxt is not None:
            heapq.heappush(heap, (nxt[0], rank, nxt[1], it))


class LeveledStore:
    """Leveled run hierarchy + persisted manifest (paper §III-D's 'leveled
    garbage collection').

    Invariants:
      * `runs` is ordered newest-first by `last_index`; boundaries strictly
        increase per GC cycle, and a merge output inherits the newest input
        boundary, so recency order == last_index order.
      * Every run at level l+1 is older than every run at level l (merges
        always consume a whole level), so levels grow geometrically and a
        single L0 flush is O(active segment), independent of total data.
      * The manifest (atomic tmp+rename) is the authority on membership:
        a run file not listed there is a crashed merge output and is
        discarded on recovery; inputs of an unfinished merge stay listed,
        so the store always recovers to a Raft-boundary-consistent state.
    """
    MANIFEST = "runs_manifest.json"

    def __init__(self, dirpath: str, metrics: Metrics,
                 cache: Optional[BlockCache] = None, fanout: int = 4):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics
        self.cache = cache
        self.fanout = fanout
        self.runs: List[SortedRun] = []      # newest first
        self.boundary: Tuple[int, int] = (0, 0)
        self.next_rid = 0
        # manifest epoch: bumps on every committed membership mutation
        # (add_l0 / commit_merge / adopt_run / install_payload).  A leader
        # and a pure-adopter follower advance it in lock-step, which makes
        # divergence between their run hierarchies observable.
        self.epoch = 0
        # run-shipping position: (leader term, ship epoch) of the newest
        # adopted record — durable in the manifest, so a restarted follower
        # resumes adoption exactly where it left off (stale or duplicated
        # records are fenced out by comparing against this).
        self.ship_pos: Tuple[int, int] = (0, 0)
        self.manifest_path = os.path.join(dirpath, self.MANIFEST)

    # ----------------------------------------------------------- manifest
    def _persist_manifest(self):
        data = {"next_rid": self.next_rid,
                "boundary": list(self.boundary),
                "epoch": self.epoch,
                "ship_pos": list(self.ship_pos),
                "runs": [{"rid": r.rid, "level": r.level,
                          "last_index": r.last_index,
                          "last_term": r.last_term} for r in self.runs]}
        # audited atomic swap: tmp fsync + rename + parent dirsync — callers
        # delete retired run files only after this returns, so a lost rename
        # can never leave the old manifest pointing at removed files
        write_json_atomic(self.manifest_path, data)
        self.metrics.on_write("gc_meta", 64)

    def alloc_rid(self) -> int:
        """Reserve a run id durably so a crashed build never collides with
        a later run of the same id."""
        rid = self.next_rid
        self.next_rid += 1
        self._persist_manifest()
        return rid

    def load(self) -> bool:
        if not os.path.exists(self.manifest_path):
            return False
        with open(self.manifest_path) as f:
            m = json.load(f)
        self.next_rid = m["next_rid"]
        self.boundary = tuple(m["boundary"])
        self.epoch = m.get("epoch", 0)
        self.ship_pos = tuple(m.get("ship_pos", (0, 0)))
        self.runs = []
        for spec in m["runs"]:
            run = SortedRun(self.dir, self.metrics, spec["rid"],
                            level=spec["level"], cache=self.cache)
            if not run.load():
                # manifest references it => data loss; fail loudly
                raise FileNotFoundError(run.path)
            self.runs.append(run)
        self.runs.sort(key=lambda r: r.last_index, reverse=True)
        return True

    def prune_orphans(self, keep: Tuple[str, ...] = ()):
        """Remove run files the manifest does not own (crashed level-merge
        outputs); `keep` protects an in-flight L0 build being resumed."""
        live = {os.path.basename(p) for r in self.runs
                for p in (r.path, r.meta_path)}
        live.update(os.path.basename(p) for p in keep)
        for fn in os.listdir(self.dir):
            if fn.startswith("run_") and fn.split(".")[-1] in ("log", "meta") \
                    and fn not in live:
                fs_remove(os.path.join(self.dir, fn))

    # ------------------------------------------------------------ mutation
    def add_l0(self, run: SortedRun, boundary: Tuple[int, int]):
        """Commit a sealed L0 run (one GC cycle's output) + new boundary."""
        run.level = 0
        self.runs.insert(0, run)
        self.boundary = boundary
        self.epoch += 1
        self._persist_manifest()

    def level_runs(self, level: int) -> List[SortedRun]:
        return [r for r in self.runs if r.level == level]

    def needs_merge(self) -> Optional[int]:
        """Lowest level holding >= fanout runs, or None."""
        levels = sorted({r.level for r in self.runs})
        for level in levels:
            if len(self.level_runs(level)) >= self.fanout:
                return level
        return None

    def commit_merge(self, out_run: SortedRun, inputs: List[SortedRun]):
        """Atomically swap merge inputs for the sealed output, THEN delete
        the input files (crash between the two leaves only orphans)."""
        drop = {r.rid for r in inputs}
        self.runs = [r for r in self.runs if r.rid not in drop]
        self.runs.append(out_run)
        self.runs.sort(key=lambda r: r.last_index, reverse=True)
        self.epoch += 1
        self._persist_manifest()
        for r in inputs:
            r.destroy()

    # ------------------------------------------------------- run shipping
    def export_run(self, run: SortedRun) -> bytes:
        """Byte payload of one sealed run, for replication to followers."""
        with open(run.path, "rb") as f:
            data = f.read()
        self.metrics.on_read("run_export", len(data))
        return data

    def adopt_run(self, level: int, last_index: int, last_term: int,
                  data: bytes, retire: List[Tuple[int, int]],
                  boundary: Tuple[int, int],
                  ship_pos: Tuple[int, int]) -> SortedRun:
        """Install a leader-sealed run wholesale and retire the same inputs
        the leader consumed — the follower side of run shipping.

        `retire` names inputs by logical identity (level, last_index) so
        adoption survives local rid renumbering (e.g. after a snapshot
        catch-up).  Raises ValueError when an input is missing — the fence
        a diverged/lagging follower trips, falling back to snapshot
        catch-up.  Crash-safe like commit_merge: the manifest swap commits
        run + retirements + ship position atomically; files of retired
        runs are deleted only after the swap (before it, the new file is
        an orphan the next recovery prunes)."""
        drop = []
        for lvl, li in retire:
            match = [r for r in self.runs
                     if r.level == lvl and r.last_index == li]
            if not match:
                raise ValueError(f"adopt fence: no input run L{lvl}@{li}")
            drop.append(match[0])
        run = SortedRun(self.dir, self.metrics, self.alloc_rid(),
                        level=level, cache=self.cache)
        run.install_payload(data, last_index, last_term,
                            category="run_adopt")
        dropset = {r.rid for r in drop}
        self.runs = [r for r in self.runs if r.rid not in dropset]
        self.runs.append(run)
        self.runs.sort(key=lambda r: r.last_index, reverse=True)
        self.boundary = tuple(boundary)
        self.ship_pos = tuple(ship_pos)
        self.epoch += 1
        self._persist_manifest()    # the adoption commit point
        for r in drop:
            r.destroy()
        return run

    # --------------------------------------------------------------- reads
    def get(self, key: bytes) -> Optional[bytes]:
        for r in self.runs:                 # newest first; bloom-gated
            v = r.get(key)
            if v is not None:
                return v
        return None

    def scan_sources(self, lo: bytes, hi: bytes):
        """Newest-first streaming iterators for the engine's k-way merge."""
        return [r.scan_iter(lo, hi) for r in self.runs]

    def total_keys(self) -> int:
        return sum(len(r.keys) for r in self.runs)

    def total_bytes(self) -> int:
        return sum(r.data_bytes() for r in self.runs)

    def level_shape(self) -> Dict[int, int]:
        shape: Dict[int, int] = {}
        for r in self.runs:
            shape[r.level] = shape.get(r.level, 0) + 1
        return shape

    # ------------------------------------------------------------ snapshot
    def snapshot_payload(self) -> List[dict]:
        """InstallSnapshot payload: the whole run set, newest first."""
        out = []
        for r in self.runs:
            with open(r.path, "rb") as f:
                data = f.read()
            self.metrics.on_ship("snapshot", len(data))
            out.append({"level": r.level, "last_index": r.last_index,
                        "last_term": r.last_term, "data": data})
        return out

    def install_payload(self, payload: List[dict], last_index: int,
                        last_term: int):
        """Write the shipped runs, swap the manifest, THEN delete the old
        files — a crash before the swap leaves the old set authoritative
        (new files are orphans), after it the old files are orphans."""
        old_runs = self.runs
        base = self.next_rid            # reserve every rid in ONE write
        self.next_rid += len(payload)
        if payload:
            self._persist_manifest()
        new_runs = []
        for i, spec in enumerate(payload):
            run = SortedRun(self.dir, self.metrics, base + i,
                            level=spec["level"], cache=self.cache)
            run.install_payload(spec["data"], spec["last_index"],
                                spec["last_term"])
            new_runs.append(run)
        new_runs.sort(key=lambda r: r.last_index, reverse=True)
        self.runs = new_runs
        self.boundary = (last_index, last_term)
        self.epoch += 1
        self.ship_pos = (0, 0)   # shipping restarts from the snapshot state
        self._persist_manifest()    # swap point
        for r in old_runs:
            r.destroy()

    def close(self):
        for r in self.runs:
            r.close()

    def destroy(self):
        for r in self.runs:
            r.destroy()
        self.runs = []
        fs_remove(self.manifest_path)
