"""Storage modules of Nezha's adaptive storage management (paper §III-C).

  * StorageModule   — Active / New: a ValueLog (raft entries incl. values,
                      appended once) + a MiniLSM index of key -> offset.
  * SortedStore     — Final Compacted Storage: key-sorted ValueLog + hash
                      index + (last_index, last_term) snapshot metadata.
                      Supports crash-resume (last key written = interrupt
                      point, paper §III-E).
  * SegmentedRaftLog— raft-index -> (module, offset) mapping that survives
                      the Active -> New role rotation across GC cycles.
"""
from __future__ import annotations

import json
import os
import struct
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.cache import BlockCache, next_namespace
from repro.core.metrics import Metrics
from repro.core.minilsm import MiniLSM
from repro.core.valuelog import KIND_PUT, LogEntry, ValueLog, _HDR

_OFF = struct.Struct("<Q")


def pack_offset(off: int) -> bytes:
    return _OFF.pack(off)


def unpack_offset(b: bytes) -> int:
    return _OFF.unpack(b)[0]


class StorageModule:
    """ValueLog + lightweight key->offset index (the paper's 'RocksDB')."""

    def __init__(self, dirpath: str, metrics: Metrics, tag: str,
                 sync: bool = False, group_commit: bool = False,
                 cache: Optional[BlockCache] = None):
        self.dir = dirpath
        self.tag = tag
        self.metrics = metrics
        self.vlog = ValueLog(os.path.join(dirpath, f"valuelog_{tag}.log"),
                             metrics, category="valuelog", sync=sync,
                             group_commit=group_commit, cache=cache)
        self.db = MiniLSM(os.path.join(dirpath, f"db_{tag}"), metrics,
                          wal=True, name=f"db_{tag}", sync=sync,
                          group_commit=group_commit, cache=cache)

    def apply(self, entry: LogEntry, offset: int):
        """State-machine apply: store ONLY the offset (Algorithm 1 line 7)."""
        self.db.put(entry.key, pack_offset(offset))

    def apply_batch(self, pairs: List[Tuple[LogEntry, int]]):
        """Group apply: all offset records become one buffered WAL write."""
        self.db.put_batch([(e.key, pack_offset(off)) for e, off in pairs])

    def sync_now(self):
        """Commit-window boundary: one fsync each for vlog + index WAL."""
        self.vlog.sync_now()
        self.db.sync_wal()

    def get_offset(self, key: bytes) -> Optional[int]:
        v = self.db.get(key)
        return None if v is None else unpack_offset(v)

    def read_value(self, offset: int) -> bytes:
        return self.vlog.read_value_at(offset)

    def get(self, key: bytes) -> Optional[bytes]:
        off = self.get_offset(key)
        return None if off is None else self.read_value(off)

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        """Range scan: sorted key->offset pairs then scattered value reads."""
        out = []
        for k, v in self.db.scan(lo, hi):
            out.append((k, self.read_value(unpack_offset(v))))
        return out

    def sorted_items(self) -> Iterator[Tuple[bytes, int]]:
        for k, v in self.db.iterate_all():
            yield k, unpack_offset(v)

    def destroy(self):
        self.vlog.delete()
        self.db.destroy()

    def close(self):
        self.vlog.close()
        self.db.close()


class SortedStore:
    """Final Compacted Storage: key-ordered ValueLog + hash index + snapshot
    metadata.  A range scan costs one hash lookup + one sequential read."""

    # stream-decode chunk size: bounds memory on the recovery/GC paths
    CHUNK_BYTES = 1 << 20

    def __init__(self, dirpath: str, metrics: Metrics, gen: int = 0,
                 cache: Optional[BlockCache] = None):
        self.dir = dirpath
        os.makedirs(dirpath, exist_ok=True)
        self.metrics = metrics
        self.gen = gen
        self.cache = cache
        self._cache_ns = next_namespace()
        self.path = os.path.join(dirpath, f"sorted_{gen:04d}.log")
        self.meta_path = os.path.join(dirpath, f"sorted_{gen:04d}.meta")
        self.index: Dict[bytes, Tuple[int, int]] = {}  # key -> (off, len)
        self.keys: List[bytes] = []                    # sorted
        self.last_index = 0
        self.last_term = 0
        self._complete = False
        self._rf = None   # persistent read handle, opened lazily

    def _reset_read_state(self):
        """File bytes changed (build/install/destroy): drop handle + cache."""
        if self._rf is not None:
            self._rf.close()
            self._rf = None
        if self.cache is not None:
            self.cache.invalidate(self._cache_ns)
            self._cache_ns = next_namespace()

    def _stream_records(self, category: Optional[str] = None
                        ) -> Iterator[Tuple[int, LogEntry]]:
        """Chunked sequential decode of (offset, entry); never materializes
        the whole file.  Bytes consumed are accounted to `category` exactly
        as the old whole-file read was (same totals, chunked ops)."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            buf = b""
            base = 0          # file offset of buf[0]
            while True:
                chunk = f.read(self.CHUNK_BYTES)
                if chunk and category is not None:
                    self.metrics.on_read(category, len(chunk))
                if not chunk and not buf:
                    return
                buf += chunk
                off = 0
                while off + _HDR.size <= len(buf):
                    _, _, _, _, klen, vlen = _HDR.unpack_from(buf, off)
                    rlen = _HDR.size + klen + vlen
                    if off + rlen > len(buf):
                        break
                    entry, _ = LogEntry.decode(buf, off)
                    yield base + off, entry
                    off += rlen
                base += off
                buf = buf[off:]
                if not chunk:
                    return  # EOF; leftover buf is a torn tail, tolerated

    # --------------------------------------------------------------- build
    def build(self, items: Iterator[Tuple[bytes, LogEntry]],
              last_index: int, last_term: int,
              resume_after: Optional[bytes] = None,
              interleave=None):
        """Write key-sorted entries.  `items` must be key-ascending.
        resume_after: crash-recovery interrupt point (skip keys <= it).
        interleave: optional callback run between entries (models async GC).
        """
        self._reset_read_state()
        mode = "ab" if resume_after is not None else "wb"
        with open(self.path, mode) as f:
            off = f.tell()
            for key, entry in items:
                if resume_after is not None and key <= resume_after:
                    continue
                data = entry.encode()
                f.write(data)
                self.metrics.on_write("gc_sorted", len(data))
                self.index[key] = (off, len(data))
                self.keys.append(key)
                off += len(data)
                if interleave is not None:
                    interleave()
        self.last_index = last_index
        self.last_term = last_term
        self._complete = True
        with open(self.meta_path, "w") as f:
            json.dump({"last_index": last_index, "last_term": last_term,
                       "complete": True}, f)
        self.metrics.on_write("gc_meta", 64)

    def last_key_on_disk(self) -> Optional[bytes]:
        """Crash-resume support: stream the partial file for its last key
        (chunked — the old implementation slurped the whole file)."""
        last = None
        try:
            for _, entry in self._stream_records("gc_resume_scan"):
                last = entry.key
        except Exception:
            pass  # torn/corrupt tail: resume from the last good key
        return last

    def load(self) -> bool:
        """Recovery: reload index from the sorted file + meta, streaming in
        CHUNK_BYTES pieces; byte totals match the old whole-file read."""
        if not os.path.exists(self.meta_path):
            return False
        if not os.path.exists(self.path):
            # meta without data = real loss; fail loudly (silently loading
            # an empty index would make every GC'd key vanish)
            raise FileNotFoundError(self.path)
        with open(self.meta_path) as f:
            meta = json.load(f)
        self.last_index = meta["last_index"]
        self.last_term = meta["last_term"]
        self.index.clear()
        self.keys = []
        for off, entry in self._stream_records("recover_sorted"):
            self.index[entry.key] = (
                off, _HDR.size + len(entry.key) + len(entry.value))
            self.keys.append(entry.key)
        self._complete = True
        self._reset_read_state()
        return True

    # --------------------------------------------------------------- reads
    def get(self, key: bytes) -> Optional[bytes]:
        loc = self.index.get(key)          # hash index: direct lookup
        if loc is None:
            return None
        if self.cache is not None:
            buf = self.cache.get(self._cache_ns, loc[0])
            if buf is not None:
                self.metrics.on_cache_hit("sorted_point")
                entry, _ = LogEntry.decode(buf, 0)
                return entry.value
        if self._rf is None:
            self._rf = open(self.path, "rb")
        self._rf.seek(loc[0])
        buf = self._rf.read(loc[1])
        self.metrics.on_read("sorted_point", len(buf))
        if self.cache is not None:
            self.cache.put(self._cache_ns, loc[0], buf)
        entry, _ = LogEntry.decode(buf, 0)
        return entry.value

    def scan(self, lo: bytes, hi: bytes) -> List[Tuple[bytes, bytes]]:
        """ONE random seek to the start key, then sequential read."""
        from bisect import bisect_left, bisect_right
        i = bisect_left(self.keys, lo)
        j = bisect_right(self.keys, hi)
        if i >= j:
            return []
        start = self.index[self.keys[i]][0]
        end_off, end_len = self.index[self.keys[j - 1]]
        if self._rf is None:
            self._rf = open(self.path, "rb")
        self._rf.seek(start)
        buf = self._rf.read(end_off + end_len - start)
        self.metrics.on_read("sorted_range", len(buf))
        out, off = [], 0
        while off < len(buf):
            entry, off = LogEntry.decode(buf, off)
            out.append((entry.key, entry.value))
        return out

    def items(self) -> Iterator[Tuple[bytes, LogEntry]]:
        for _, entry in self._stream_records("gc_merge_read"):
            yield entry.key, entry

    def snapshot_payload(self) -> bytes:
        """Whole sorted file — Raft InstallSnapshot payload for catch-up."""
        with open(self.path, "rb") as f:
            data = f.read()
        self.metrics.on_read("snapshot_ship", len(data))
        return data

    def install_payload(self, payload: bytes, last_index: int,
                        last_term: int):
        self._reset_read_state()
        with open(self.path, "wb") as f:
            f.write(payload)
        self.metrics.on_write("snapshot_install", len(payload))
        with open(self.meta_path, "w") as f:
            json.dump({"last_index": last_index, "last_term": last_term,
                       "complete": True}, f)
        self.load()

    def destroy(self):
        self._reset_read_state()
        for p in (self.path, self.meta_path):
            if os.path.exists(p):
                os.remove(p)
