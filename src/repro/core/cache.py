"""Read-path caching primitives shared across the storage stack.

  * BloomFilter — per-SSTable membership filter so point gets skip files
    that cannot contain the key (zero read bytes on a negative).
  * BlockCache  — a small shared LRU of (namespace, block) -> bytes used by
    SSTable blocks, SortedStore point records, and ValueLog offset reads.
    One cache per engine: hot blocks of every layer compete for the same
    budget, mirroring how a real block cache sits below the whole engine.

Namespaces make invalidation cheap: every cached file owner draws a token
from `next_namespace()` and bumps it when its bytes change (truncate,
rewrite, delete), abandoning stale entries without scanning the LRU.
"""
from __future__ import annotations

import itertools
import zlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

_NS_COUNTER = itertools.count(1)


def next_namespace() -> int:
    """Process-unique token identifying one immutable version of a file."""
    return next(_NS_COUNTER)


class BloomFilter:
    """Split-hash bloom filter over byte keys (~1% fp at 10 bits/key).
    Bits live in a bytearray so add() is O(k), not O(filter_size)."""

    def __init__(self, n_items: int, bits_per_key: int = 10, n_hashes: int = 7):
        self.m = max(64, n_items * bits_per_key)
        self.k = n_hashes
        self._bits = bytearray((self.m + 7) // 8)

    @classmethod
    def from_keys(cls, keys, bits_per_key: int = 10,
                  n_hashes: int = 7) -> "BloomFilter":
        """Build a filter sized for `keys` (per-sorted-run point-get gate:
        a negative membership test skips the run with zero I/O)."""
        keys = list(keys)
        bf = cls(len(keys), bits_per_key, n_hashes)
        for k in keys:
            bf.add(k)
        return bf

    def _probes(self, key: bytes):
        h1 = zlib.crc32(key)
        h2 = zlib.adler32(key) | 1      # odd => cycles through all slots
        for i in range(self.k):
            yield (h1 + i * h2) % self.m

    def add(self, key: bytes):
        for p in self._probes(key):
            self._bits[p >> 3] |= 1 << (p & 7)

    def __contains__(self, key: bytes) -> bool:
        bits = self._bits
        return all(bits[p >> 3] & (1 << (p & 7)) for p in self._probes(key))


class BlockCache:
    """Byte-budgeted LRU keyed by (namespace, block_id)."""

    def __init__(self, capacity_bytes: int = 2 << 20,
                 max_entry_bytes: Optional[int] = None):
        self.capacity = capacity_bytes
        self.max_entry = max_entry_bytes or max(capacity_bytes // 8, 4096)
        self._lru: "OrderedDict[Tuple[int, int], bytes]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0

    def get(self, ns: int, block_id: int) -> Optional[bytes]:
        key = (ns, block_id)
        data = self._lru.get(key)
        if data is None:
            self.misses += 1
            return None
        self._lru.move_to_end(key)
        self.hits += 1
        return data

    def put(self, ns: int, block_id: int, data: bytes):
        if len(data) > self.max_entry:
            return
        key = (ns, block_id)
        old = self._lru.pop(key, None)
        if old is not None:
            self._bytes -= len(old)
        self._lru[key] = data
        self._bytes += len(data)
        while self._bytes > self.capacity and self._lru:
            _, evicted = self._lru.popitem(last=False)
            self._bytes -= len(evicted)

    def invalidate(self, ns: int):
        """Drop every entry of one namespace (file truncated/rewritten)."""
        stale = [k for k in self._lru if k[0] == ns]
        for k in stale:
            self._bytes -= len(self._lru.pop(k))

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "bytes": self._bytes, "entries": len(self._lru)}
