"""Run-shipping replication: leader-driven GC with follower run adoption.

With run shipping enabled, the LEADER is the only node that performs GC
flushes and leveled merges.  Every run it seals (an L0 flush of the active
segment, or a level-merge output) becomes a *run-adoption record* — the run's
bytes plus a manifest delta (level, Raft boundary, the input identities to
retire, the new store boundary) — that is chunked and streamed to followers
over SimNet.  A follower installs the sealed run wholesale and retires the
same inputs instead of re-running GC locally, so cluster-wide compaction
rewrite work drops from N× to 1× (the RDMA index-replication design of
Vardoulakis et al., adapted to whole immutable runs).

Protocol (ShipRun / ShipRunReply in raft.py):

  * Records are totally ordered by pos = (leader term, ship epoch) and must
    be adopted in order; the follower's durable position lives in the runs
    manifest (LeveledStore.ship_pos), so restarts resume exactly.
  * Chunks are resumable: the follower acks its contiguous prefix (`have`);
    the leader sends a bounded window past it and retransmits on a timeout,
    so crashes, partitions and lossy links mid-ship never lose a record —
    they only delay it.
  * Adoption is ordered against AppendEntries: a record installs only once
    the follower has APPLIED the log through the record's last_index, so
    adopted state can never race ahead of the replicated log.
  * Fencing: a record carries the leader's pre-mutation store boundary and
    the logical identities (level, last_index) of the runs it retires.  A
    follower whose manifest does not match exactly (diverged, missed an
    epoch the leader already trimmed, crashed mid-sequence, was mid-local-GC
    as a deposed leader) answers `resync` and the leader falls back to
    InstallSnapshot-style catch-up — never divergence.

Durability: this module keeps NO durable state of its own.  In-flight
chunk assemblies are volatile by design — kill -9 anywhere (the FaultFS
crash-point sweep injects mid-adoption crashes) loses at most the record
in flight, which the leader retransmits from ship_pos; the one durable
cursor is LeveledStore.ship_pos, committed inside the adoption's atomic
manifest swap (see the durability contract in engines.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core import trace as _trace
from repro.core.metrics import Metrics
from repro.core.raft import LEADER, RaftNode, ShipRun, ShipRunReply

_NEVER = -(10 ** 9)


class _PeerShip:
    """Leader-side per-follower shipping cursor."""
    __slots__ = ("pos", "have", "last_send", "snap_at", "snap_tip",
                 "snap_li")

    def __init__(self):
        self.pos: Tuple[int, int] = (0, 0)  # follower's durable position
        self.have = 0             # chunks acked for the record in flight
        self.last_send = _NEVER   # net.time of the last window sent
        self.snap_at = _NEVER     # last fence-fallback snapshot sent
        self.snap_tip: Tuple[int, int] = (0, 0)  # records an in-flight
        self.snap_li: Optional[int] = None       # snapshot supersedes, and
        #                                          that snapshot's last_index


class RunShipper:
    """Leader side: queue sealed-run records, stream chunks, track acks."""

    def __init__(self, node: RaftNode, engine, metrics: Metrics, *,
                 chunk_bytes: int = 8 << 10, window: int = 4,
                 retry_ticks: int = 12, max_records: int = 16,
                 snap_interval: int = 50):
        self.node = node
        self.engine = engine
        self.metrics = metrics
        self.chunk_bytes = chunk_bytes
        self.window = window
        self.retry_ticks = retry_ticks
        self.max_records = max_records
        self.snap_interval = snap_interval
        self.epoch = 0
        self.records = []   # [(pos, rec, data)], pos-ascending, bounded
        self.peers: Dict[int, _PeerShip] = {p: _PeerShip()
                                            for p in node.peers}

    def sync_peers(self):
        """Membership changed: open a fresh cursor for every new member
        (a joining learner starts from pos (0,0) and is caught up by
        snapshot + resumable chunks) and drop removed members so their
        stale cursor can no longer pin records in _prune."""
        for p in self.node.peers:
            self.peers.setdefault(p, _PeerShip())
        for gone in set(self.peers) - set(self.node.peers):
            del self.peers[gone]
        self._prune()

    # ------------------------------------------------------------ sealing
    def on_run_sealed(self, rec: dict, data: bytes):
        """Engine hook: a run was just committed to the leader manifest."""
        node = self.node
        if node.role != LEADER or not node.peers:
            return
        self.epoch += 1
        pos = (node.current_term, self.epoch)
        nchunks = max(1, -(-len(data) // self.chunk_bytes))
        t = _trace._ACTIVE
        rec = dict(rec, pos=pos, size=len(data), nchunks=nchunks,
                   ctx=t.current() if t is not None else 0)
        self.records.append((pos, rec, data))
        if len(self.records) > self.max_records:
            # a follower that still needs a trimmed record will trip the
            # epoch-gap check below and be caught up by snapshot instead
            self.records = self.records[-self.max_records:]
        for ps in self.peers.values():
            ps.last_send = _NEVER   # dispatch on the next tick

    def _target(self, ps: _PeerShip):
        for pos, rec, data in self.records:
            if pos > ps.pos:
                return pos, rec, data
        return None

    # --------------------------------------------------------------- send
    def tick(self):
        node = self.node
        if node.role != LEADER:
            return
        if set(self.peers) != set(node.peers):
            self.sync_peers()   # config changed while we weren't leader
        if not self.records:
            return
        now = node.net.time
        for p, ps in self.peers.items():
            tgt = self._target(ps)
            if tgt is None:
                continue
            pos, rec, data = tgt
            if pos[0] == ps.pos[0] and pos[1] > ps.pos[1] + 1:
                # the record after the follower's position was trimmed:
                # the sequence is broken, only a snapshot can catch it up
                self._resync(p, ps, now)
                continue
            if now - ps.last_send < self.retry_ticks:
                continue    # window in flight; retransmit on timeout
            self._send_window(p, ps, rec, data, now)

    def _send_window(self, peer: int, ps: _PeerShip, rec: dict, data: bytes,
                     now: int):
        node = self.node
        nchunks = rec["nchunks"]
        # have == nchunks: everything delivered, follower is waiting on its
        # apply barrier — re-send the last chunk as a probe so its eventual
        # adoption ack (or a crash-reset `have`) can't be lost for good
        lo = min(ps.have, nchunks - 1)
        hi = max(min(ps.have + self.window, nchunks), lo + 1)
        for seq in range(lo, hi):
            chunk = data[seq * self.chunk_bytes:(seq + 1) * self.chunk_bytes]
            self.metrics.on_ship("run", len(chunk))
            node.net.send(node.addr, node._addr(peer),
                          ShipRun(node.current_term, node.nid, rec, seq,
                                  chunk), size=len(chunk))
        ps.last_send = now

    # -------------------------------------------------------------- acks
    def on_reply(self, src: int, m: ShipRunReply):
        node = self.node
        if m.term > node.current_term:
            node._become_follower(m.term)
            return
        if node.role != LEADER or m.term != node.current_term:
            return
        ps = self.peers.get(src)
        if ps is None:
            return
        if m.resync:
            self._resync(src, ps, node.net.time)
            return
        adopted = tuple(m.adopted)
        if adopted > ps.pos:
            ps.pos = adopted          # record(s) installed: advance
            ps.have = 0
            ps.last_send = _NEVER
            self._prune()
        tgt = self._target(ps)
        if tgt is not None and tuple(m.pos) == tgt[0] and m.have != ps.have:
            ps.have = m.have          # progress (or a restart's reset)
            ps.last_send = _NEVER     # extend the window immediately

    def _resync(self, peer: int, ps: _PeerShip, now: int):
        """Fence fallback: the follower can't adopt from where it is — ship
        the whole run set via InstallSnapshot (rate-limited); the send hook
        (on_snapshot_sent) skips the cursor past every covered record."""
        if now - ps.snap_at < self.snap_interval:
            return
        ps.snap_at = now
        self.node.send_snapshot_to(peer)

    def on_snapshot_sent(self, peer: int, last_index: int):
        """Any snapshot to `peer` (log catch-up or fence fallback) carries
        the whole current run set, superseding every record sealed so far.
        Only remember that fact here — the cursor skips when the INSTALL is
        acked, so a snapshot dropped by the network keeps old records (and
        the fence/resync retry loop) alive until one actually lands."""
        ps = self.peers.get(peer)
        if ps is None:
            return
        ps.snap_at = self.node.net.time
        if self.records:
            ps.snap_tip = self.records[-1][0]
            ps.snap_li = last_index

    def on_snapshot_acked(self, peer: int, match_index: int):
        """InstallSnapshotReply from `peer`: skip the cursor only if the
        ack proves THIS send's state (or newer) is in — a stale reply to
        an earlier snapshot must not bury records a dropped one carried."""
        ps = self.peers.get(peer)
        if ps is None or ps.snap_li is None or match_index < ps.snap_li:
            return
        if ps.snap_tip > ps.pos:
            ps.pos = ps.snap_tip
            ps.have = 0
            ps.last_send = _NEVER
            self._prune()

    def _prune(self):
        """Drop payloads every follower has passed — a record's bytes are
        pinned only while some peer may still need them (bounded anyway
        by max_records for crashed/unreachable peers)."""
        if self.peers and self.records:
            low = min(ps.pos for ps in self.peers.values())
            self.records = [r for r in self.records if r[0] > low]


class RunAdopter:
    """Follower side: assemble chunks, fence-check, install via the engine."""

    def __init__(self, node: RaftNode, engine, metrics: Metrics):
        self.node = node
        self.engine = engine
        self.metrics = metrics
        self.buf: Optional[dict] = None   # record being assembled
        self.pending: Optional[Tuple[dict, bytes]] = None  # awaiting apply
        self.awaiting_resync = False

    @property
    def pos(self) -> Tuple[int, int]:
        """Durable ship position — lives in the runs manifest."""
        return tuple(self.engine.leveled.ship_pos)

    # ------------------------------------------------------------ receive
    def on_chunk(self, src: int, m: ShipRun):
        node = self.node
        if m.term > node.current_term:
            node._become_follower(m.term)
        if m.term < node.current_term:
            self._reply(src, tuple(m.rec["pos"]), 0)
            return
        node.leader_id = m.leader
        node._note_leader_contact()       # ship traffic IS leader liveness
        rec = m.rec
        pos = tuple(rec["pos"])
        if self.awaiting_resync:
            # keep asking (the leader rate-limits): the requested snapshot
            # may have been dropped by the network
            self._reply(src, pos, 0, resync=True)
            return
        if pos <= self.pos:
            self._reply(src, pos, rec["nchunks"])   # duplicate: already in
            return
        if self.pending is not None:
            if pos == tuple(self.pending[0]["pos"]):
                self._reply(src, pos, rec["nchunks"])
                self._try_adopt(src)
            return      # never buffer ahead of an uninstalled record
        if self.buf is None or tuple(self.buf["rec"]["pos"]) != pos:
            if self.buf is not None and pos < tuple(self.buf["rec"]["pos"]):
                return  # stale chunk of an older record
            self.buf = {"rec": rec, "chunks": {}, "have": 0}
        b = self.buf
        if m.seq not in b["chunks"]:
            b["chunks"][m.seq] = m.data
            while b["have"] in b["chunks"]:
                b["have"] += 1          # contiguous prefix length
        self._reply(src, pos, b["have"])
        if b["have"] >= rec["nchunks"]:
            data = b"".join(b["chunks"][i] for i in range(rec["nchunks"]))
            self.pending = (rec, data)
            self.buf = None
            self._try_adopt(src)

    def tick(self):
        """Apply-barrier poll: a fully-received record installs as soon as
        the log has applied through its boundary."""
        if self.pending is not None and self.node.leader_id is not None:
            self._try_adopt(self.node.leader_id)

    # ------------------------------------------------------------- adopt
    def _try_adopt(self, reply_to: int):
        rec, data = self.pending
        node, eng = self.node, self.engine
        if node.last_applied < rec["last_index"]:
            return      # ordered behind AppendEntries: wait for apply
        t = _trace._ACTIVE
        # graft onto the leader-side GC span that sealed the run (its id
        # crossed the wire in the record); a ctx from a since-replaced
        # tracer shows up as a flagged orphan, never silently dropped
        sid = t.begin("adopt_run", kind="ship", node=node.addr,
                      parent=rec.get("ctx", 0),
                      level=rec.get("level"),
                      last_index=rec["last_index"]) if t is not None else None
        try:
            ok, new_offsets = eng.adopt_run(rec, data)
            if sid is not None:
                t.tag(sid, ok=bool(ok))
            self.pending = None
            if not ok:
                self.awaiting_resync = True
                self._reply(reply_to, tuple(rec["pos"]), 0, resync=True)
                return
            if rec["kind"] == "flush":
                # the adopted run covers the log through last_index:
                # compact the in-memory log like the leader did, then
                # re-point the surviving tail at its rewritten offsets
                node.compact_to(rec["last_index"], rec["last_term"])
                node.repoint_offsets(new_offsets)
            self._reply(reply_to, tuple(rec["pos"]), rec["nchunks"])
        finally:
            if sid is not None:
                t.end(sid)

    def _reply(self, dst: int, pos: Tuple[int, int], have: int,
               resync: bool = False):
        node = self.node
        node.net.send(node.addr, node._addr(dst), ShipRunReply(
            node.current_term, pos, have, self.pos, resync))

    def reset(self):
        """An installed snapshot supersedes anything in flight."""
        self.buf = None
        self.pending = None
        self.awaiting_resync = False
