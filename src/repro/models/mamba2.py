"""Mamba-2 (SSD) block — chunked parallel scan for train/prefill, recurrent
state update for decode.  Follows the SSD formulation of arXiv:2405.21060
(single B/C group), adapted to TPU: all intra-chunk work is batched einsum
(MXU-friendly), the only sequential dependency is a length-``n_chunks``
``lax.scan`` over 128-token chunks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm


def _dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    return di, nh, cfg.ssm_state, cfg.ssm_conv


def mamba2_init(key, cfg):
    d = cfg.d_model
    di, nh, N, K = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * N
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + nh), dt),
        "conv_w": dense_init(ks[1], (K, conv_dim), dt, fan_in=K),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[3], (di, d), dt, fan_in=di),
    }


def _split_proj(params, x, cfg):
    di, nh, N, _ = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., -nh:]
    return z, xBC, dt_raw


def _causal_conv(xBC, params, K):
    """Depthwise causal conv along S. xBC: (B,S,C)."""
    pads = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xBC.shape[1]] * params["conv_w"][i]
              for i in range(K))
    return jax.nn.silu(out + params["conv_b"])


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """x:(b,s,h,p) dt:(b,s,h) A:(h,) Bm,Cm:(b,s,n). Returns y, final_state."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Q = min(chunk, s)
    nc = s // Q
    xf = x.astype(jnp.float32).reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = Bm.astype(jnp.float32).reshape(b, nc, Q, n)
    Cc = Cm.astype(jnp.float32).reshape(b, nc, Q, n)
    dA = dtc * A                                    # (b,nc,Q,h), A<0
    cum = jnp.cumsum(dA, axis=2)
    # intra-chunk
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,Q,Q,h)
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tril[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    Y = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp", scores, L, dtc, xf)
    # per-chunk input states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (b,nc,Q,h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, decay_end * dtc, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (b,nc,h)

    def scanf(S_prev, inp):
        st, dec = inp                                      # (b,h,p,n), (b,h)
        S_new = S_prev * dec[..., None, None] + st
        return S_new, S_prev

    S0 = (init_state if init_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))
    S_final, S_in = jax.lax.scan(
        scanf, S0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    Yoff = jnp.einsum("bcqn,cbhpn->bcqhp", Cc, S_in) * \
        jnp.exp(cum)[..., None]
    y = (Y + Yoff).reshape(b, s, h, p)
    return y.astype(x.dtype), S_final


def init_mamba_cache(cfg, batch: int, dtype=None):
    di, nh, N, K = _dims(cfg)
    dt = dtype or jnp.dtype(cfg.param_dtype)
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di + 2 * N), dt),
    }


def mamba2_apply(params, x, cfg, rules, *, mode="train", cache=None, pos=None):
    """x: (B,S,d) (train/prefill) or (B,1,d) (decode)."""
    di, nh, N, K = _dims(cfg)
    hp = cfg.ssm_head_dim
    B = x.shape[0]
    A = -jnp.exp(params["A_log"])
    if mode == "decode":
        z, xBC, dt_raw = _split_proj(params, x[:, 0], cfg)   # (B, ·)
        conv_buf = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)
        xBC_c = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", conv_buf, params["conv_w"])
            + params["conv_b"])
        new_conv = conv_buf[:, 1:]
        xs = xBC_c[..., :di].reshape(B, nh, hp).astype(jnp.float32)
        Bm = xBC_c[..., di:di + N].astype(jnp.float32)
        Cm = xBC_c[..., di + N:].astype(jnp.float32)
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        dA = jnp.exp(dtv * A)                                # (B,nh)
        S = cache["ssm"] * dA[..., None, None] + \
            jnp.einsum("bh,bn,bhp->bhpn", dtv, Bm, xs)
        y = jnp.einsum("bn,bhpn->bhp", Cm, S) + xs * params["D"][:, None]
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_cache = {"ssm": S, "conv": new_conv}
        z = z[:, None]
    else:
        z, xBC, dt_raw = _split_proj(params, x, cfg)
        xBC_c = _causal_conv(xBC, params, K)
        xs = xBC_c[..., :di].reshape(B, x.shape[1], nh, hp)
        Bm = xBC_c[..., di:di + N]
        Cm = xBC_c[..., di + N:]
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        if rules is not None:
            xs = rules.constrain(xs, "batch", None, "heads")
        y, S_final = ssd_chunked(xs, dtv, A, Bm, Cm, chunk=128)
        y = y + xs.astype(jnp.float32) * params["D"][:, None]
        y = y.reshape(B, x.shape[1], di).astype(x.dtype)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = {"ssm": S_final, "conv": xBC[:, -(K - 1):]}
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], new_cache
