"""Model assembly: repeating-unit block stacks scanned over layers.

The per-layer block pattern (cfg.block_pattern) is a repeating unit; params
for each unit position are stacked over repeats and the stack is traversed
with ``lax.scan`` so the HLO contains each distinct block exactly once
(fast multi-pod compiles, MaxText-style).  Zamba2's shared attention block is
closure-captured (weights shared) and applied every ``shared_attn_every``
layers through ``lax.cond``; its per-application KV caches ride in the scan
carry with dynamic indexing.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_MOE, MAMBA2, MLSTM, SLSTM, ModelConfig
from repro.models import attention, mamba2, moe as moe_mod, xlstm
from repro.models.layers import embed, embed_init, mlp, mlp_init, rmsnorm, \
    rmsnorm_init, unembed

PyTree = Any


# ------------------------------------------------------------------- init
def _block_init(key, cfg, kind):
    ks = jax.random.split(key, 4)
    p = {"ln1": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.param_dtype))}
    if kind in (ATTN, ATTN_MOE):
        p["attn"] = attention.attn_init(ks[0], cfg)
        p["ln2"] = rmsnorm_init(cfg.d_model, jnp.dtype(cfg.param_dtype))
        if kind == ATTN:
            p["mlp"] = mlp_init(ks[1], cfg)
        else:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
    elif kind == MAMBA2:
        p["mamba"] = mamba2.mamba2_init(ks[0], cfg)
    elif kind == MLSTM:
        p["mlstm"] = xlstm.mlstm_init(ks[0], cfg)
    elif kind == SLSTM:
        p["slstm"] = xlstm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def n_repeats(cfg) -> int:
    assert cfg.n_layers % len(cfg.block_pattern) == 0, \
        f"{cfg.name}: n_layers must divide by unit length"
    return cfg.n_layers // len(cfg.block_pattern)


def init_params(key, cfg: ModelConfig) -> PyTree:
    reps = n_repeats(cfg)
    keys = jax.random.split(key, 3 + len(cfg.block_pattern))
    layers = []
    for pi, kind in enumerate(cfg.block_pattern):
        stacked = jax.vmap(
            lambda k, kind=kind: _block_init(k, cfg, kind))(
                jax.random.split(keys[pi], reps))
        layers.append(stacked)
    params = {
        "embed": embed_init(keys[-3], cfg),
        "layers": tuple(layers),
        "final_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.param_dtype)),
    }
    if cfg.shared_attn_every:
        params["shared_attn"] = {
            "ln": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "attn": attention.attn_init(keys[-2], cfg),
            "ln2": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "mlp": mlp_init(keys[-1], cfg),
        }
    return params


# ------------------------------------------------------------------ cache
def _block_cache(cfg, kind, batch, max_seq, layout):
    if kind in (ATTN, ATTN_MOE):
        return attention.init_attn_cache(cfg, batch, max_seq, layout)
    if kind == MAMBA2:
        return mamba2.init_mamba_cache(cfg, batch)
    if kind == MLSTM:
        return xlstm.init_mlstm_cache(cfg, batch)
    if kind == SLSTM:
        return xlstm.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_seq: int, layout: str = "dense") -> PyTree:
    reps = n_repeats(cfg)
    caches = {"layers": tuple(
        jax.tree.map(lambda x: jnp.broadcast_to(x, (reps,) + x.shape),
                     _block_cache(cfg, kind, batch, max_seq, layout))
        for kind in cfg.block_pattern)}
    if cfg.shared_attn_every:
        n_apps = cfg.n_layers // cfg.shared_attn_every
        sc = attention.init_attn_cache(cfg, batch, max_seq, layout)
        caches["shared"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_apps,) + x.shape), sc)
    return caches


# ------------------------------------------------------------------ apply
def _block_apply(kind, p, x, cfg, rules, mode, cache, pos):
    eps = cfg.norm_eps
    h = rmsnorm(p["ln1"], x, eps)
    new_cache = None
    if kind in (ATTN, ATTN_MOE):
        a, new_cache = attention.attn_apply(p["attn"], h, cfg, rules,
                                            mode=mode, cache=cache, pos=pos)
        x = x + a
        h2 = rmsnorm(p["ln2"], x, eps)
        if kind == ATTN:
            x = x + mlp(p["mlp"], h2, cfg)
        else:
            x = x + moe_mod.moe_apply(p["moe"], h2, cfg, rules)
    elif kind == MAMBA2:
        y, new_cache = mamba2.mamba2_apply(p["mamba"], h, cfg, rules,
                                           mode=mode, cache=cache, pos=pos)
        x = x + y
    elif kind == MLSTM:
        y, new_cache = xlstm.mlstm_apply(p["mlstm"], h, cfg, rules,
                                         mode=mode, cache=cache, pos=pos)
        x = x + y
    elif kind == SLSTM:
        y, new_cache = xlstm.slstm_apply(p["slstm"], h, cfg, rules,
                                         mode=mode, cache=cache, pos=pos)
        x = x + y
    if rules is not None:
        seq = "act_seq" if cfg.seq_shard else None
        x = rules.constrain(x, "batch", seq, None)
    return x, new_cache


def _shared_attn_apply(p, x, cfg, rules, mode, cache, pos):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    a, new_cache = attention.attn_apply(p["attn"], h, cfg, rules,
                                        mode=mode, cache=cache, pos=pos)
    x = x + a
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + mlp(p["mlp"], h2, cfg)
    return x, new_cache


def forward(params, inputs, cfg: ModelConfig, rules=None, *, mode="train",
            caches=None, pos=None, return_hidden=False):
    """inputs: (B,S) int tokens or (B,S,d) embeds.  Returns (logits, caches)."""
    x = embed(params["embed"], inputs, cfg)
    if rules is not None:
        x = rules.constrain(x, "batch",
                            "act_seq" if cfg.seq_shard else None, None)
    unit = cfg.block_pattern
    use_cache = caches is not None
    every = cfg.shared_attn_every

    def body(x, xs):
        layer_params, layer_caches = xs
        new_caches = []
        for pi, kind in enumerate(unit):
            c_i = layer_caches[pi] if use_cache else None
            x, nc = _block_apply(kind, layer_params[pi], x, cfg, rules,
                                 mode, c_i, pos)
            new_caches.append(nc if nc is not None else 0)
        return x, tuple(new_caches)

    if mode == "train" and cfg.remat != "none":
        policy = {"full": jax.checkpoint_policies.nothing_saveable,
                  "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                  }[cfg.remat]
        body = jax.checkpoint(body, policy=policy)

    def seg_scan(x, p_seg, c_seg):
        xs = (p_seg, c_seg if use_cache else jnp.zeros(
            (jax.tree.leaves(p_seg)[0].shape[0],)))
        return jax.lax.scan(body, x, xs)

    if every:
        # zamba2-style: shared attention block applied (with its own cache
        # slot) after every `every` backbone layers — statically unrolled
        # into groups so the HLO and its cost analysis reflect the true
        # per-layer mix (no lax.cond over-/under-counting).
        assert len(unit) == 1, "shared_attn requires a unit-1 block pattern"
        shared_p = params["shared_attn"]
        groups = cfg.n_layers // every
        rem = cfg.n_layers - groups * every
        p_all = params["layers"][0]
        c_all = caches["layers"][0] if use_cache else None
        seg_caches, shared_caches = [], []
        for g in range(groups):
            sl = slice(g * every, (g + 1) * every)
            p_seg = jax.tree.map(lambda a: a[sl], p_all)
            c_seg = jax.tree.map(lambda a: a[sl], c_all) if use_cache else None
            x, c_out = seg_scan(x, (p_seg,), (c_seg,))
            if use_cache:
                seg_caches.append(c_out[0])
            sc = (jax.tree.map(lambda a: a[g], caches["shared"])
                  if use_cache else None)
            x, sc_out = _shared_attn_apply(shared_p, x, cfg, rules, mode,
                                           sc, pos)
            if use_cache:
                shared_caches.append(sc_out)
        if rem:
            sl = slice(groups * every, cfg.n_layers)
            p_seg = jax.tree.map(lambda a: a[sl], p_all)
            c_seg = jax.tree.map(lambda a: a[sl], c_all) if use_cache else None
            x, c_out = seg_scan(x, (p_seg,), (c_seg,))
            if use_cache:
                seg_caches.append(c_out[0])
        new_caches = None
        if use_cache:
            new_caches = {
                "layers": (jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches),),
                "shared": jax.tree.map(
                    lambda *xs: jnp.stack([u.astype(xs[0].dtype) for u in xs],
                                          axis=0), *shared_caches),
            }
    else:
        x, layer_caches_out = seg_scan(
            x, params["layers"], caches["layers"] if use_cache else None)
        new_caches = {"layers": layer_caches_out} if use_cache else None

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, new_caches
    logits = unembed(params["embed"], x, cfg)
    if rules is not None:
        logits = rules.constrain(logits, "batch", None, "vocab")
    return logits, new_caches


def lm_loss(logits, labels, mask=None):
    """Mean next-token cross-entropy. logits:(B,S,V) labels:(B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def lm_loss_chunked(params, hidden, labels, cfg, rules=None):
    """Cross-entropy computed in sequence chunks: the (B, S, V) logits
    tensor never materializes (per-chunk unembed + CE under jax.checkpoint).
    Memory: O(B * loss_chunk * V) instead of O(B * S * V)."""
    B, S, d = hidden.shape
    c = min(cfg.loss_chunk, S)
    n = S // c
    hc = jnp.moveaxis(hidden.reshape(B, n, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)

    @jax.checkpoint
    def body(acc, xs):
        h, l = xs
        logits = unembed(params["embed"], h, cfg)
        if rules is not None:
            logits = rules.constrain(logits, "batch", None, "vocab")
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, l[..., None], axis=-1)[..., 0]
        return acc + (lse - ll).sum(), ()

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)
