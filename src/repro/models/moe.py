"""Token-choice top-k Mixture-of-Experts FFN.

Expert parallelism over the ``model`` mesh axis via ``jax.shard_map``:
tokens stay sharded over (pod, data) and *replicated* over ``model``; each
model-rank owns E/model_size experts, dispatches locally (capacity-bounded
scatter), runs its expert GEMMs, scatters back, and the per-rank partial
outputs are psum-combined over ``model`` — the same collective volume as a
tensor-parallel MLP (one all-reduce of the token activations), with zero
cross-rank dispatch traffic.

For tiny token counts (decode) a dense no-drop path computes every expert and
masks, avoiding capacity drops on the serving path.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map
from repro.models.layers import dense_init


def moe_init(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "experts_wi": dense_init(ks[1], (E, d, ff), dt),
        "experts_wg": dense_init(ks[2], (E, d, ff), dt),
        "experts_wo": dense_init(ks[3], (E, ff, d), dt, fan_in=ff),
    }


def _route(xt, router_w, top_k):
    logits = xt.astype(jnp.float32) @ router_w          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)            # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return topv, topi, probs


def _expert_ffn(buf, wi, wg, wo):
    """buf: (E, C, d) -> (E, C, d) via per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_dense_nodrop(xt, p, cfg):
    """All-experts dense path (small T): no capacity drops."""
    topv, topi, _ = _route(xt, p["router"], cfg.top_k)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["experts_wg"])) * \
        jnp.einsum("td,edf->tef", xt, p["experts_wi"])
    y_all = jnp.einsum("tef,efd->ted", h, p["experts_wo"])  # (T, E, d)
    w = jnp.zeros(y_all.shape[:2], jnp.float32)
    w = w.at[jnp.arange(xt.shape[0])[:, None], topi].add(topv)
    return jnp.einsum("ted,te->td", y_all.astype(jnp.float32), w).astype(xt.dtype)


def _moe_local(xt, router_w, wi, wg, wo, *, cfg, E_local, model_axis):
    """Body run per model-rank under shard_map. xt: (T_local, d)."""
    T, d = xt.shape
    k, E = cfg.top_k, cfg.n_experts
    topv, topi, _ = _route(xt, router_w, k)
    rank = jax.lax.axis_index(model_axis) if model_axis else 0
    lo = rank * E_local
    e_flat = topi.reshape(-1)                           # (T*k,)
    w_flat = topv.reshape(-1)
    is_local = (e_flat >= lo) & (e_flat < lo + E_local)
    e_loc = jnp.where(is_local, e_flat - lo, E_local)   # E_local = drop bucket
    onehot = jax.nn.one_hot(e_loc, E_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos, e_loc[:, None], axis=1)[:, 0]
    C = max(int(cfg.capacity_factor * k * T / E), 1)
    keep = is_local & (pos < C)
    e_sc = jnp.where(keep, e_loc, E_local)              # scatter drop row
    p_sc = jnp.where(keep, pos, 0)
    x_rep = jnp.repeat(xt, k, axis=0)                   # (T*k, d)
    buf = jnp.zeros((E_local + 1, C, d), xt.dtype)
    buf = buf.at[e_sc, p_sc].add(x_rep * keep[:, None].astype(xt.dtype))
    y = _expert_ffn(buf[:E_local], wi, wg, wo)          # (E_local, C, d)
    y = jnp.concatenate([y, jnp.zeros((1, C, d), y.dtype)], axis=0)
    gathered = y[e_sc, p_sc] * (w_flat * keep)[:, None].astype(y.dtype)
    out = gathered.reshape(T, k, d).sum(axis=1)
    if model_axis:
        out = jax.lax.psum(out, model_axis)
    return out.astype(xt.dtype)


def moe_apply(params, x, cfg, rules):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    mesh = rules.mesh if rules is not None else None
    if mesh is None or "model" not in mesh.axis_names:
        if B * S <= 4096:
            out = _moe_dense_nodrop(xt, params, cfg)
        else:
            out = _moe_local(xt, params["router"], params["experts_wi"],
                             params["experts_wg"], params["experts_wo"],
                             cfg=cfg, E_local=cfg.n_experts, model_axis=None)
        return out.reshape(B, S, d)

    n_model = mesh.shape["model"]
    E_local = cfg.n_experts // n_model
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    T_local = (B * S) // functools.reduce(
        lambda a, b: a * mesh.shape[b], dp, 1)
    P = jax.sharding.PartitionSpec
    if T_local * cfg.top_k <= 2 * cfg.n_experts:
        # decode-scale: dense no-drop path, experts sharded by the einsum
        out = _moe_dense_nodrop(xt, params, cfg)
        return out.reshape(B, S, d)
    fn = functools.partial(_moe_local, cfg=cfg, E_local=E_local,
                           model_axis="model")
    out = _shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(dp, None),
    )(xt, params["router"], params["experts_wi"], params["experts_wg"],
      params["experts_wo"])
    return out.reshape(B, S, d)
