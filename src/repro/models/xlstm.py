"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, strictly sequential scan with exponential-gating
stabilizer).  Recurrent decode paths carry O(1) state per sequence — these are
the sub-quadratic archs that make ``long_500k`` runnable.

mLSTM is implemented chunkwise (same segsum machinery as SSD): per-head scalar
forget decay (log-sigmoid, hence stable cumulative sums) + exp input gate
(clamped), matrix state C:(p,p) and normalizer n:(p,) carried across chunks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm

ICLAMP = 8.0


# ================================================================== mLSTM
def _mdims(cfg):
    di = 2 * cfg.d_model
    nh = cfg.n_heads
    return di, nh, di // nh


def mlstm_init(key, cfg):
    d = cfg.d_model
    di, nh, hd = _mdims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dt),
        "wq": dense_init(ks[1], (di, di), dt),
        "wk": dense_init(ks[2], (di, di), dt),
        "wv": dense_init(ks[3], (di, di), dt),
        "w_if": dense_init(ks[4], (di, 2 * nh), jnp.float32),
        "b_if": jnp.zeros((2 * nh,), jnp.float32),
        "head_norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[5], (di, d), dt, fan_in=di),
    }


def init_mlstm_cache(cfg, batch: int, dtype=None):
    di, nh, hd = _mdims(cfg)
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
    }


def _mlstm_chunked(q, k, v, logf, logi, chunk, C0=None, n0=None):
    """q,k,v: (b,s,h,p) f32; logf<=0, logi: (b,s,h). Returns y, (C,n)."""
    b, s, h, p = q.shape
    Q = min(chunk, s)
    nc = s // Q
    qc = q.reshape(b, nc, Q, h, p)
    kc = k.reshape(b, nc, Q, h, p)
    vc = v.reshape(b, nc, Q, h, p)
    lf = logf.reshape(b, nc, Q, h)
    li = logi.reshape(b, nc, Q, h)
    cf = jnp.cumsum(lf, axis=2)
    # intra-chunk: D[t,j] = exp(cf[t]-cf[j]+li[j]) causal
    diff = cf[:, :, :, None, :] - cf[:, :, None, :, :] + li[:, :, None, :, :]
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    D = jnp.where(tril[None, None, :, :, None], jnp.exp(diff), 0.0)
    qk = jnp.einsum("bcqhp,bckhp->bcqkh", qc, kc)
    w = qk * D                                          # (b,nc,Q,Q,h)
    y_in = jnp.einsum("bcqkh,bckhp->bcqhp", w, vc)
    den_in = jnp.sum(w, axis=3)                         # (b,nc,Q,h)
    # chunk states
    decay_end = jnp.exp(cf[:, :, -1:, :] - cf + li)     # (b,nc,Q,h)
    C_chunk = jnp.einsum("bckh,bckhp,bckhr->bchpr", decay_end, kc, vc)
    n_chunk = jnp.einsum("bckh,bckhp->bchp", decay_end, kc)
    cdecay = jnp.exp(cf[:, :, -1, :])                   # (b,nc,h)

    def scanf(carry, inp):
        C, n = carry
        Cc, nc_, dec = inp
        C2 = C * dec[..., None, None] + Cc
        n2 = n * dec[..., None] + nc_
        return (C2, n2), (C, n)

    C0 = C0 if C0 is not None else jnp.zeros((b, h, p, p), jnp.float32)
    n0 = n0 if n0 is not None else jnp.zeros((b, h, p), jnp.float32)
    (Cf, nf), (C_in, n_in) = jax.lax.scan(
        scanf, (C0, n0),
        (jnp.moveaxis(C_chunk, 1, 0), jnp.moveaxis(n_chunk, 1, 0),
         jnp.moveaxis(cdecay, 1, 0)))
    g = jnp.exp(cf)                                     # (b,nc,Q,h)
    y_off = jnp.einsum("bcqhp,cbhpr->bcqhr", qc, C_in) * g[..., None]
    den_off = jnp.einsum("bcqhp,cbhp->bcqh", qc, n_in) * g
    den = jnp.maximum(jnp.abs(den_in + den_off), 1.0)[..., None]
    y = (y_in + y_off) / den
    return y.reshape(b, s, h, p), (Cf, nf)


def mlstm_apply(params, x, cfg, rules, *, mode="train", cache=None, pos=None):
    B, S, d = x.shape
    di, nh, hd = _mdims(cfg)
    xz = x @ params["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    q = (xm @ params["wq"]).reshape(B, S, nh, hd).astype(jnp.float32)
    k = (xm @ params["wk"]).reshape(B, S, nh, hd).astype(jnp.float32) * hd ** -0.5
    v = (xm @ params["wv"]).reshape(B, S, nh, hd).astype(jnp.float32)
    gates = xm.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    logi = jnp.clip(gates[..., :nh], -ICLAMP, ICLAMP)
    logf = jax.nn.log_sigmoid(gates[..., nh:])
    if mode == "decode":
        f = jnp.exp(logf[:, 0])                         # (B,nh)
        i = jnp.exp(logi[:, 0])
        C = cache["C"] * f[..., None, None] + \
            i[..., None, None] * jnp.einsum("bhp,bhr->bhpr", k[:, 0], v[:, 0])
        n = cache["n"] * f[..., None] + i[..., None] * k[:, 0]
        num = jnp.einsum("bhp,bhpr->bhr", q[:, 0], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q[:, 0], n)), 1.0)
        y = (num / den[..., None])[:, None]             # (B,1,nh,hd)
        new_cache = {"C": C, "n": n}
    else:
        C0 = n0 = None
        if mode == "prefill" and cache is not None:
            C0, n0 = cache["C"], cache["n"]
        y, (Cf, nf) = _mlstm_chunked(q, k, v, logf, logi, chunk=128,
                                     C0=C0, n0=n0)
        new_cache = {"C": Cf, "n": nf} if mode == "prefill" else None
    y = y.reshape(B, -1, di).astype(x.dtype)
    y = rmsnorm({"scale": params["head_norm_scale"]}, y, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], new_cache


# ================================================================== sLSTM
def _sdims(cfg):
    nh = cfg.n_heads
    return nh, cfg.d_model // nh


def slstm_init(key, cfg):
    d = cfg.d_model
    nh, hd = _sdims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    ffd = int(4 * d / 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), jnp.float32),
        "r_gates": dense_init(ks[1], (nh, hd, 4 * hd), jnp.float32, fan_in=hd),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "gn_scale": jnp.ones((d,), dt),
        "ff_wi": dense_init(ks[2], (d, ffd), dt),
        "ff_wo": dense_init(ks[3], (ffd, d), dt, fan_in=ffd),
    }


def init_slstm_cache(cfg, batch: int, dtype=None):
    nh, hd = _sdims(cfg)
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, nh), jnp.float32)}


def _slstm_cell(state, wx, r_gates, nh, hd):
    """One timestep. wx: (B, 4d) input preactivations."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rx = jnp.einsum("bhp,hpq->bhq", h, r_gates)          # (B,nh,4hd)
    pre = wx.reshape(wx.shape[0], nh, 4 * hd) + rx
    zt = jnp.tanh(pre[..., :hd])
    it = pre[..., hd:2 * hd]
    ft = pre[..., 2 * hd:3 * hd]
    ot = jax.nn.sigmoid(pre[..., 3 * hd:])
    # exponential gating with stabilizer (per head: use max over head dim)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf.max(-1) + m, it.max(-1))    # (B,nh)
    i_p = jnp.exp(jnp.clip(it - m_new[..., None], -ICLAMP, ICLAMP))
    f_p = jnp.exp(jnp.clip(logf + (m - m_new)[..., None], -ICLAMP, ICLAMP))
    c2 = f_p * c + i_p * zt
    n2 = f_p * n + i_p
    h2 = ot * c2 / jnp.maximum(jnp.abs(n2), 1.0)
    return {"c": c2, "n": n2, "h": h2, "m": m_new}


def slstm_apply(params, x, cfg, rules, *, mode="train", cache=None, pos=None):
    B, S, d = x.shape
    nh, hd = _sdims(cfg)
    wx = x.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    state = cache if cache is not None else init_slstm_cache(cfg, B)
    if mode == "decode":
        state = _slstm_cell(state, wx[:, 0], params["r_gates"], nh, hd)
        y = state["h"][:, None].reshape(B, 1, d)
        new_cache = state
    else:
        def body(st, wxt):
            st2 = _slstm_cell(st, wxt, params["r_gates"], nh, hd)
            return st2, st2["h"]
        state_f, hs = jax.lax.scan(body, state, jnp.moveaxis(wx, 1, 0))
        y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
        new_cache = state_f if mode == "prefill" else None
    y = rmsnorm({"scale": params["gn_scale"]}, y.astype(x.dtype), cfg.norm_eps)
    y = y + jax.nn.gelu(y @ params["ff_wi"]) @ params["ff_wo"]
    return y, new_cache
