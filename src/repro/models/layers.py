"""Core layer primitives: norms, RoPE, MLPs, embeddings, initializers.

Pure-functional JAX: parameters are pytrees of jnp arrays, every layer is
``apply(params, x, ...) -> y``.  All matmul-bearing ops keep activations in the
config dtype (bf16 by default) with reductions in f32 where it matters.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- MLP
def mlp_init(key, cfg):
    d, ff, dt = cfg.d_model, cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {"wi": dense_init(ks[0], (d, ff), dt),
                "wg": dense_init(ks[1], (d, ff), dt),
                "wo": dense_init(ks[2], (ff, d), dt, fan_in=ff)}
    return {"wi": dense_init(ks[0], (d, ff), dt),
            "wo": dense_init(ks[2], (ff, d), dt, fan_in=ff)}


def mlp(params, x, cfg):
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


# -------------------------------------------------------------- Embeddings
def embed_init(key, cfg):
    dt = _dtype(cfg)
    p: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        p["embedding"] = dense_init(key, (cfg.vocab_size, cfg.d_model), dt,
                                    fan_in=cfg.d_model)
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1),
                                  (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed(params, tokens_or_embeds, cfg):
    if cfg.input_kind == "tokens":
        return jnp.take(params["embedding"], tokens_or_embeds, axis=0)
    return tokens_or_embeds.astype(_dtype(cfg))


def unembed(params, x, cfg):
    if cfg.tie_embeddings and cfg.input_kind == "tokens":
        w = params["embedding"].T
    else:
        w = params["unembed"]
    return x @ w
