from repro.models.transformer import (forward, init_cache, init_params,
                                      lm_loss)  # noqa: F401
