"""GQA attention: chunked-causal (train/prefill) + cached decode.

Two decode cache layouts, mirroring the paper's storage states (DESIGN.md §2):
  * ``paged``  — block pool + per-sequence block table (scattered ValueLog):
                 (B, n_blocks, block, n_kv, hd) with a logical->physical table.
  * ``dense``  — contiguous cache (sorted ValueLog, i.e. post-GC/compaction):
                 (B, S, n_kv, hd).

The train/prefill path is a pure-jnp flash-attention equivalent (query-chunked,
f32 logsumexp) whose arithmetic matches kernels/flash_attention; on TPU the
Pallas kernel is substituted via kernels.flash_attention.ops.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rmsnorm

NEG_INF = -1e30


def attn_init(key, cfg):
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nh * hd), dt),
        "wk": dense_init(ks[1], (d, nkv * hd), dt),
        "wv": dense_init(ks[2], (d, nkv * hd), dt),
        "wo_attn": dense_init(ks[3], (nh * hd, d), dt, fan_in=nh * hd),
    }
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((nh * hd,), dt)
        p["wk_bias"] = jnp.zeros((nkv * hd,), dt)
        p["wv_bias"] = jnp.zeros((nkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((hd,), dt)
        p["k_norm_scale"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["wq_bias"]
        k = k + params["wk_bias"]
        v = v + params["wv_bias"]
    q = q.reshape(B, S, nh, hd)
    k = k.reshape(B, S, nkv, hd)
    v = v.reshape(B, S, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm({"scale": params["q_norm_scale"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm_scale"]}, k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, rep, rules=None):
    """(B,S,nkv,hd) -> (B,S,nkv*rep,hd).  GQA heads are expanded BEFORE the
    score einsum so the full head axis (divisible by the model axis) carries
    the tensor-parallel sharding; a (nkv, rep) split reshape would break
    GSPMD propagation and silently replicate attention (observed: 16x compute
    + 245GiB temps on qwen2-72b before this fix)."""
    if rep == 1:
        return k
    k = jnp.repeat(k, rep, axis=2)
    if rules is not None:
        k = rules.constrain(k, "batch", None, "heads")
    return k


def _sdpa_chunk(qc, k, v, q_pos, kv_pos, scale):
    """One query chunk against full K/V. qc:(B,C,nh,hd) k/v:(B,S,nh,hd)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = (kv_pos[None, :] <= q_pos[:, None])          # (C, S) causal
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF)))
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o


def chunked_causal_attention(q, k, v, *, q_offset=0, chunk=512, rules=None):
    """q: (B,Sq,nh,hd); k,v: (B,Skv,nkv,hd). Returns (B,Sq,nh,hd)."""
    B, Sq, nh, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    rep = nh // nkv
    scale = hd ** -0.5
    k = _repeat_kv(k, rep, rules)
    v = _repeat_kv(v, rep, rules)
    kv_pos = jnp.arange(Skv)
    chunk = min(chunk, Sq)
    n_chunks = Sq // chunk
    if n_chunks <= 1:
        o = _sdpa_chunk(q, k, v, jnp.arange(Sq) + q_offset, kv_pos, scale)
        return o.astype(q.dtype)

    qg = q.reshape(B, n_chunks, chunk, nh, hd)

    @jax.checkpoint
    def body(carry, xs):
        qc, start = xs
        q_pos = start + jnp.arange(chunk) + q_offset
        o = _sdpa_chunk(qc, k, v, q_pos, kv_pos, scale)
        return carry, o

    starts = jnp.arange(n_chunks) * chunk
    _, o = jax.lax.scan(body, (), (jnp.moveaxis(qg, 1, 0), starts))
    o = jnp.moveaxis(o, 0, 1).reshape(B, Sq, nh, hd)
    return o.astype(q.dtype)


# ------------------------------------------------------------ cache layouts
def init_attn_cache(cfg, batch: int, max_seq: int, layout: str, dtype=None):
    nkv, hd, bs = cfg.n_kv_heads, cfg.hd, cfg.kv_block_size
    dt = dtype or jnp.dtype(cfg.param_dtype)
    if layout == "dense":
        return {
            "k": jnp.zeros((batch, max_seq, nkv, hd), dt),
            "v": jnp.zeros((batch, max_seq, nkv, hd), dt),
        }
    n_blk = max_seq // bs
    return {
        "pool_k": jnp.zeros((batch, n_blk, bs, nkv, hd), dt),
        "pool_v": jnp.zeros((batch, n_blk, bs, nkv, hd), dt),
        # logical block -> physical block (identity = fully compacted)
        "table": jnp.tile(jnp.arange(n_blk, dtype=jnp.int32)[None], (batch, 1)),
    }


def _decode_attend(q, k_all, v_all, pos, nh, rules):
    """q:(B,1,nh,hd) vs full cache (B,S,nkv,hd) masked to <=pos.

    Grouped (no KV repeat): the cache is read once — decode is HBM-bound and
    an nh/nkv-fold repeat would overstate the memory roofline term 8x.  The
    (nkv, rep) head split only touches q, which is tiny at decode.  The big
    dims (batch, cache_seq) keep their sharding; softmax reductions over a
    sharded S lower to the flash-decoding split-K all-reduce pattern."""
    B, S, nkv, hd = k_all.shape
    rep = nh // nkv
    scale = hd ** -0.5
    qg = q.reshape(B, 1, nkv, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                   k_all.astype(jnp.float32)) * scale
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))    # per-seq positions
    mask = jnp.arange(S)[None, :] <= pos_b[:, None]     # (B, S)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v_all.astype(jnp.float32))
    den = jnp.sum(p, axis=-1)[..., None].transpose(0, 3, 1, 2, 4)
    o = o / jnp.maximum(den, 1e-30)
    return o.reshape(B, 1, nh * hd)


def attn_decode(params, cache, x, pos, cfg, rules):
    """One-token decode. x:(B,1,d); pos: scalar OR per-sequence (B,) index
    (continuous batching serves ragged sequences in one lockstep batch)."""
    B = x.shape[0]
    nh, nkv, hd, bs = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.kv_block_size
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None]
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    bi = jnp.arange(B)
    if "k" in cache:  # dense / compacted layout
        k_all = cache["k"].at[bi, pos_b].set(k_new[:, 0])
        v_all = cache["v"].at[bi, pos_b].set(v_new[:, 0])
        new_cache = {"k": k_all, "v": v_all}
        if rules is not None:
            k_all = rules.constrain(k_all, "batch", "cache_seq")
            v_all = rules.constrain(v_all, "batch", "cache_seq")
    else:  # paged layout: write via block table, read via gather
        blk = jnp.take_along_axis(cache["table"], (pos_b // bs)[:, None],
                                  axis=1)[:, 0]                  # (B,)
        slot = pos_b % bs
        pool_k = cache["pool_k"].at[bi, blk, slot].set(k_new[:, 0])
        pool_v = cache["pool_v"].at[bi, blk, slot].set(v_new[:, 0])
        new_cache = dict(cache, pool_k=pool_k, pool_v=pool_v)
        tbl = cache["table"][..., None, None, None]              # (B,nblk,1,1,1)
        k_all = jnp.take_along_axis(pool_k, tbl, axis=1)
        v_all = jnp.take_along_axis(pool_v, tbl, axis=1)
        n_blk = k_all.shape[1]
        k_all = k_all.reshape(B, n_blk * bs, nkv, hd)
        v_all = v_all.reshape(B, n_blk * bs, nkv, hd)
        if rules is not None:
            k_all = rules.constrain(k_all, "batch", "cache_seq")
            v_all = rules.constrain(v_all, "batch", "cache_seq")
    o = _decode_attend(q, k_all, v_all, pos, nh, rules)
    out = o.astype(x.dtype) @ params["wo_attn"]
    return out, new_cache


def attn_apply(params, x, cfg, rules, *, mode="train", cache=None, pos=None,
               chunk=512):
    """Unified entry. Returns (out, new_cache)."""
    if mode == "decode":
        return attn_decode(params, cache, x, pos, cfg, rules)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None]
    q, k, v = _project_qkv(params, x, cfg, positions)
    if rules is not None and cfg.attn_seq_parallel and mode != "decode":
        # context parallelism: q rows sharded over `model`, K/V replicated;
        # each rank computes its strip of the score matrix (no head-count
        # divisibility requirement — see DESIGN.md §6 / EXPERIMENTS §Perf)
        q = rules.constrain(q, "batch", "act_seq", None, None)
        k = rules.constrain(k, "batch", None, None, None)
        v = rules.constrain(v, "batch", None, None, None)
        rep = cfg.n_heads // cfg.n_kv_heads
        o = _sdpa_chunk(q, _repeat_kv(k, rep), _repeat_kv(v, rep),
                        jnp.arange(S), jnp.arange(S), cfg.hd ** -0.5)
        o = o.astype(q.dtype)
    else:
        if rules is not None:
            q = rules.constrain(q, "batch", None, "heads")
            k = rules.constrain(k, "batch", None, "kv_heads")
            v = rules.constrain(v, "batch", None, "kv_heads")
        o = chunked_causal_attention(q, k, v, chunk=chunk, rules=rules)
    out = o.reshape(B, S, -1) @ params["wo_attn"]
    new_cache = None
    if mode == "prefill" and cache is not None:
        if "k" in cache:
            k_pad = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
            v_pad = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
            new_cache = {"k": k_pad, "v": v_pad}
        else:
            bs = cfg.kv_block_size
            kb = k.reshape(B, S // bs, bs, *k.shape[2:])
            vb = v.reshape(B, S // bs, bs, *v.shape[2:])
            # write THROUGH the block table (physical placement may be
            # scattered — the serving allocator owns the table)
            dest = cache["table"][:, :S // bs]               # (B, nwb)
            bi = jnp.arange(B)[:, None]
            pool_k = cache["pool_k"].at[bi, dest].set(kb)
            pool_v = cache["pool_v"].at[bi, dest].set(vb)
            new_cache = dict(cache, pool_k=pool_k, pool_v=pool_v)
    return out, new_cache
