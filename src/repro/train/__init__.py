from repro.train.optimizer import adamw_update, init_opt_state  # noqa: F401
