"""AdamW with global-norm clipping, built from scratch (no optax).

Moment buffers live in f32 regardless of param dtype; the update is computed
in f32 and cast back.  m/v inherit the parameter sharding (ZeRO-style: the
optimizer state is sharded exactly like the FSDP'd parameters, so optimizer
memory scales 1/(dp*tp))."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptHyper(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params: PyTree) -> Tuple[PyTree, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(params: PyTree, grads: PyTree, m: PyTree, v: PyTree,
                 step: jnp.ndarray, hyper: OptHyper = OptHyper()):
    """Returns (new_params, new_m, new_v, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hyper.clip_norm / jnp.maximum(gnorm, 1e-9))
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - hyper.b1 ** t
    bc2 = 1.0 - hyper.b2 ** t

    def upd(p, g, m_, v_):
        g = g.astype(jnp.float32) * scale
        m2 = hyper.b1 * m_ + (1 - hyper.b1) * g
        v2 = hyper.b2 * v_ + (1 - hyper.b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        pf = p.astype(jnp.float32)
        pf = pf - hyper.lr * (mhat / (jnp.sqrt(vhat) + hyper.eps)
                              + hyper.weight_decay * pf)
        return pf.astype(p.dtype), m2, v2

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = jax.tree.leaves(grads)
    m_flat = jax.tree.leaves(m)
    v_flat = jax.tree.leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_
           in zip(p_flat, g_flat, m_flat, v_flat)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v, gnorm
