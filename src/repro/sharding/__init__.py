from repro.sharding.rules import Rules, make_rules  # noqa: F401
