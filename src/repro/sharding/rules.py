"""Logical-axis sharding rules -> PartitionSpec / NamedSharding.

Model code annotates activations with *logical* axis names; the rules map them
to physical mesh axes.  This keeps the model mesh-agnostic: the same code runs
on (data, model), (pod, data, model), or a single device (rules=None).

Physical layout (DESIGN.md §4):
  batch          -> (pod?, data)              data parallel
  heads/ff/vocab/experts -> model             tensor / expert parallel
  fsdp (weight dim 0)    -> (pod?, data)      ZeRO-style param+opt sharding
  cache_seq      -> model, or (pod?, data, model) for batch-1 long context
Any axis that does not divide the dimension is dropped (guarded specs), so
e.g. batch=1 decode falls back gracefully.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import path_str


def fit_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim; pad/trim rank."""
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    fixed = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            fixed.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        keep, size = [], 1
        for a in axs:
            if a not in mesh.axis_names:
                continue
            n = mesh.shape[a]
            if dim % (size * n) == 0:
                keep.append(a)
                size *= n
        fixed.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*fixed)


@dataclass
class Rules:
    mesh: Optional[Mesh]
    table: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    def spec(self, *logical) -> P:
        if self.mesh is None:
            return P()
        axes, used = [], set()
        for name in logical:
            phys = self.table.get(name) if name else None
            if not phys:
                axes.append(None)
                continue
            avail = tuple(a for a in phys
                          if a not in used and a in self.mesh.axis_names)
            used.update(avail)
            if not avail:
                axes.append(None)
            else:
                axes.append(avail if len(avail) != 1 else avail[0])
        return P(*axes)

    def sharding(self, shape, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, fit_spec(shape, self.spec(*logical),
                                                 self.mesh))

    def constrain(self, x, *logical):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.sharding(x.shape, *logical))


def make_rules(mesh: Optional[Mesh], *, seq_shard_cache: bool = False) -> Rules:
    """seq_shard_cache: shard the KV-cache sequence dim over (dp..., model) —
    used for batch-1 long-context decode (distributed attention reduction)."""
    if mesh is None:
        return Rules(mesh=None)
    has_pod = "pod" in mesh.axis_names
    dp = ("pod", "data") if has_pod else ("data",)
    table = {
        "batch": dp,
        "fsdp": dp,
        "embed_fsdp": dp,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "cache_seq": dp + ("model",) if seq_shard_cache else ("model",),
        "act_seq": ("model",),   # sequence-parallel residual stream
    }
    return Rules(mesh=mesh, table=table)


# ------------------------------------------------------------ param specs
def param_spec(path, shape: Tuple[int, ...], rules: Rules) -> P:
    """Sharding spec for one parameter, keyed by its pytree path."""
    if rules.mesh is None:
        return P()
    name = path if isinstance(path, str) else path_str(path)
    leaf = name.rsplit("/", 1)[-1]
    stacked = "layers" in name  # leading repeat dim from the layer scan
    base_rank = len(shape) - (1 if stacked else 0)

    if base_rank <= 1 or "bias" in leaf or "scale" in leaf or leaf in (
            "A_log", "D", "dt_bias", "b_if", "b_gates"):
        logical: Tuple[Optional[str], ...] = (None,) * base_rank
    elif leaf == "embedding":
        logical = ("vocab", "embed_fsdp")
    elif leaf == "unembed":
        logical = ("embed_fsdp", "vocab")
    elif leaf in ("wq", "wk", "wv"):
        logical = ("embed_fsdp", "heads")
    elif leaf == "wo_attn":
        logical = ("heads", "embed_fsdp")
    elif leaf.startswith("experts_"):
        logical = ("experts", "fsdp", None)
    elif leaf == "router":
        logical = ("embed_fsdp", None)
    elif leaf in ("wi", "wg", "ff_wi", "in_proj", "w_gates", "w_if"):
        logical = ("embed_fsdp", "ff")
    elif leaf in ("wo", "ff_wo", "out_proj"):
        logical = ("ff", "embed_fsdp")
    elif leaf == "conv_w":
        logical = (None, "ff")
    elif leaf == "r_gates":
        logical = ("heads", None, None)
    else:
        logical = ("embed_fsdp",) + (None,) * (base_rank - 1)

    spec = rules.spec(*logical)
    if stacked:
        spec = P(None, *spec)
    return fit_spec(shape, spec, rules.mesh)


def cache_spec(path, shape: Tuple[int, ...], rules: Rules) -> P:
    """Sharding spec for a KV/state-cache leaf (leading dim = layer repeats)."""
    if rules.mesh is None:
        return P()
    name = path if isinstance(path, str) else path_str(path)
    leaf = name.rsplit("/", 1)[-1]
    if leaf in ("k", "v"):              # (reps, B, S, nkv, hd)
        logical = ("batch", "cache_seq", None, None)
    elif leaf in ("pool_k", "pool_v"):  # (reps, B, nblk, bs, nkv, hd)
        logical = ("batch", "cache_seq", None, None, None)
    elif leaf == "table":               # (reps, B, nblk)
        logical = ("batch", None)
    elif leaf in ("ssm", "C"):          # (reps, B, nh, ...)
        logical = ("batch", "heads", None, None)
    else:                               # small recurrent state
        logical = ("batch",) + (None,) * (len(shape) - 2)
    spec = P(None, *rules.spec(*logical))
    return fit_spec(shape, spec, rules.mesh)


def tree_specs(tree, spec_fn, rules: Rules):
    """Map a spec function over a pytree of ShapeDtypeStructs/arrays."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(rules.mesh, spec_fn(path_str(p), l.shape, rules))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
