"""Deterministic synthetic token pipeline with host sharding + prefetch.

Each global step has a unique seed derived from (base_seed, step), so a
restarted-from-checkpoint run replays the exact same batches — the property
the fault-tolerance integration test asserts (bit-identical loss curves
across a crash/restore boundary).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1, prefetch: int = 2,
                 start_step: int = 0):
        assert shape.global_batch % n_hosts == 0
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def batch_for_step(self, step: int) -> dict:
        """Pure function of (seed, step, host): restart-safe."""
        B = self.shape.global_batch // self.n_hosts
        S = self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        if self.cfg.input_kind == "embeds":
            tokens = rng.standard_normal((B, S, self.cfg.d_model),
                                         dtype=np.float32)
        else:
            tokens = rng.integers(0, self.cfg.vocab_size, (B, S),
                                  dtype=np.int32)
        labels = rng.integers(0, self.cfg.vocab_size, (B, S), dtype=np.int32)
        return {"tokens": tokens, "labels": labels}

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_for_step(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
