from repro.data.pipeline import TokenPipeline  # noqa: F401
