"""DeepSeek-7B — llama-arch dense LM [arXiv:2401.02954; hf]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="deepseek_7b", family="dense", n_layers=30, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab_size=102400, head_dim=128,
    block_pattern=(ATTN,), tie_embeddings=False,
    source="arXiv:2401.02954",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=160, vocab_size=128)
