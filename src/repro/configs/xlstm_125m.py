"""xLSTM-125M — alternating mLSTM + sLSTM blocks [arXiv:2405.04517;
unverified].  d_ff=0: xLSTM blocks carry their own projections."""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm_125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50304, head_dim=192,
    block_pattern=(MLSTM, SLSTM), tie_embeddings=True,
    source="arXiv:2405.04517",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                       head_dim=32, vocab_size=128)
