"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060; hf]."""
from repro.configs.base import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="olmoe_1b_7b", family="moe", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, top_k=8, block_pattern=(ATTN_MOE,), tie_embeddings=False,
    qk_norm=True, source="arXiv:2409.02060",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=64, vocab_size=128, n_experts=8,
                       top_k=2)
