"""Chameleon-34B backbone — early-fusion, VQ image tokens in a unified vocab
[arXiv:2405.09818; unverified].  Frontend is a STUB: VQ-tokenized inputs are
ordinary token ids inside the 65536 vocab; qk-norm per the paper."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="chameleon_34b", family="vlm", n_layers=48, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab_size=65536, head_dim=128, qk_norm=True,
    grad_accum=4,  # fits 16GiB HBM (see EXPERIMENTS.md §Perf)
    block_pattern=(ATTN,), tie_embeddings=False,
    source="arXiv:2405.09818",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=160, vocab_size=128)
