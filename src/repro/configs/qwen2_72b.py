"""Qwen2-72B — GQA + QKV bias dense LM [arXiv:2407.10671; hf]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2_72b", family="dense", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=29568, vocab_size=152064, head_dim=128, qkv_bias=True,
    grad_accum=4,  # fits 16GiB HBM (see EXPERIMENTS.md §Perf)
    block_pattern=(ATTN,), tie_embeddings=False, rope_theta=1e6,
    source="arXiv:2407.10671",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=160, vocab_size=128)
