"""Architecture configuration schema + registry.

Every assigned architecture gets one module in ``repro/configs`` exporting
``CONFIG`` (the exact published figures) and ``SMOKE`` (a reduced config of the
same family for CPU smoke tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by repro.models.transformer
ATTN = "attn"          # GQA attention + MLP (dense transformer layer)
ATTN_MOE = "attn_moe"  # GQA attention + MoE FFN
MAMBA2 = "mamba2"      # Mamba-2 (SSD) block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads
    # attention variants
    qk_norm: bool = False            # qwen3 / chameleon
    qkv_bias: bool = False           # qwen2
    rope_theta: float = 10_000.0
    mlp_kind: str = "swiglu"         # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # layer pattern: repeating unit of block kinds, cycled to n_layers
    block_pattern: Tuple[str, ...] = (ATTN,)
    # zamba2-style shared attention block applied every k-th layer (0 = off)
    shared_attn_every: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    # frontends
    input_kind: str = "tokens"       # tokens | embeds (stub modality frontend)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    # precision
    param_dtype: str = "bfloat16"
    # sequence parallelism: shard the residual stream's seq dim over the
    # model axis (Megatron-SP pattern; GSPMD inserts the AG/RS pairs).
    # Without it the remat-saved layer inputs alone exceed HBM on the
    # large archs (measured: 245GiB/dev for qwen2-72b train_4k).
    seq_shard: bool = True
    # pin gradients to the param (fsdp, model) sharding => reduce-scatter
    # instead of per-layer full all-reduce in the backward scan
    grad_shard: bool = True
    # sequence-parallel ATTENTION: shard the query seq dim over the model
    # axis inside attention (context parallelism).  The rescue for archs
    # whose head count does not divide the model axis (smollm: 9 heads vs
    # model=16 => 16x replicated attention compute without this).
    attn_seq_parallel: bool = False
    # chunked cross-entropy: compute unembed+CE in seq chunks of this many
    # tokens (0 = off).  Kills the (B, S, V) logits transient.
    loss_chunk: int = 0
    # training
    remat: str = "full"              # none | dots | full
    grad_accum: int = 1
    # serving
    kv_block_size: int = 64          # paged KV cache block size (tokens)
    # citation provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def pattern_for_layers(self) -> Tuple[str, ...]:
        """Full per-layer block kinds (len == n_layers)."""
        unit = self.block_pattern
        out = tuple(unit[i % len(unit)] for i in range(self.n_layers))
        return out

    @property
    def has_attention(self) -> bool:
        return (any(k in (ATTN, ATTN_MOE) for k in self.pattern_for_layers())
                or self.shared_attn_every > 0)

    @property
    def attention_free(self) -> bool:
        return not self.has_attention

    @property
    def subquadratic(self) -> bool:
        """True if sequence mixing is sub-quadratic (SSM / linear recurrent),
        allowing the long_500k shape."""
        kinds = set(self.pattern_for_layers())
        return kinds <= {MAMBA2, MLSTM, SLSTM} or (
            kinds <= {MAMBA2, MLSTM, SLSTM, ATTN} and self.shared_attn_every > 0
            and ATTN not in kinds)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP accounting (for roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        mlp = (3 if self.mlp_kind == "swiglu" else 2) * d * ff
        moe = self.n_experts * (3 * d * ff) + d * self.n_experts if self.is_moe else 0
        di = self.ssm_expand * d
        nh_ssm = max(di // self.ssm_head_dim, 1)
        mamba = (d * (2 * di + 2 * self.ssm_state + nh_ssm)  # in_proj(z,x)+B,C+dt
                 + self.ssm_conv * (di + 2 * self.ssm_state) + di * d + 2 * nh_ssm)
        for kind in self.pattern_for_layers():
            total += 2 * d  # norms
            if kind == ATTN:
                total += attn + mlp
            elif kind == ATTN_MOE:
                total += attn + moe
            elif kind == MAMBA2:
                total += mamba
            elif kind == MLSTM:
                di_m = 2 * d  # up-projection factor 2
                # in: d->2*di (x and gate z); qkv: 3 projections di->di; out di->d
                total += d * 2 * di_m + 3 * di_m * di_m + di_m * d
            elif kind == SLSTM:
                # 4 gates d->d recurrent cell + FFN with pf=4/3 (up+down)
                total += 4 * d * d + 2 * d * int(4 * d / 3)
        if self.shared_attn_every > 0:
            total += attn + mlp  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dead = self.n_experts * 3 * d * ff - self.top_k * 3 * d * ff
        n_moe_layers = sum(1 for k in self.pattern_for_layers() if k == ATTN_MOE)
        return int(self.param_count() - dead * n_moe_layers)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "smollm_135m", "deepseek_7b", "qwen2_72b", "qwen3_8b", "musicgen_medium",
    "chameleon_34b", "zamba2_1p2b", "olmoe_1b_7b", "dbrx_132b", "xlstm_125m",
]


def get(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_is_skipped(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return skip reason or None.  long_500k only runs for sub-quadratic archs
    (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "long_500k skipped: pure full-attention arch (quadratic prefill); see DESIGN.md"
    return None
