"""Qwen3-8B — qk-norm + GQA dense LM [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3_8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=12288, vocab_size=151936, head_dim=128, qk_norm=True,
    block_pattern=(ATTN,), tie_embeddings=False, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=160, vocab_size=128)
