"""DBRX-132B — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base;
unverified]."""
from repro.configs.base import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="dbrx_132b", family="moe", n_layers=40, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=10752, vocab_size=100352, head_dim=128,
    n_experts=16, top_k=4, block_pattern=(ATTN_MOE,), tie_embeddings=False,
    grad_accum=8,  # 33.9 -> 16.2 GiB/dev (EXPERIMENTS.md §Dry-run)
    rope_theta=5e5, source="hf:databricks/dbrx-base",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=64, vocab_size=128, n_experts=4,
                       top_k=2)
