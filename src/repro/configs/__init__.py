from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                cell_is_skipped, get)  # noqa: F401
