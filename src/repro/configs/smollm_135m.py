"""SmolLM-135M — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="smollm_135m", family="dense", n_layers=30, d_model=576, n_heads=9,
    n_kv_heads=3, d_ff=1536, vocab_size=49152, head_dim=64,
    block_pattern=(ATTN,), tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
                       head_dim=16, d_ff=96, vocab_size=128)
