"""MusicGen-medium backbone — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  Modality frontend is a STUB: input_specs() supplies
precomputed frame embeddings (B, S, d_model); the LM head predicts the 2048
EnCodec codewords."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048, head_dim=64,
    mlp_kind="gelu", input_kind="embeds", block_pattern=(ATTN,),
    tie_embeddings=False, source="arXiv:2306.05284",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=64)
