"""Zamba2-1.2B — Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242; hf].  38 Mamba2 layers; one weight-shared attention+MLP
block applied at layers 6,12,...,36 (6 applications, each with its own KV
cache)."""
from repro.configs.base import MAMBA2, ModelConfig

CONFIG = ModelConfig(
    name="zamba2_1p2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000, head_dim=64,
    grad_accum=4,  # 35.3 -> 9.4 GiB/dev (EXPERIMENTS.md §Dry-run)
    block_pattern=(MAMBA2,), shared_attn_every=6, ssm_state=64,
    ssm_head_dim=64, tie_embeddings=True,
    source="arXiv:2411.15242",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=128,
                       shared_attn_every=2, ssm_state=16, ssm_head_dim=16)
