"""Version-compat shims for the installed jax (0.4.x through 0.6.x APIs).

Every "jax renamed/moved X" fallback lives here so the next rename is a
one-file fix: AxisType (absent before 0.5), shard_map (promoted to the
top-level namespace in 0.6), pallas TPUCompilerParams -> CompilerParams.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map


def pallas_compiler_params(pltpu):
    """The pallas-TPU compiler-params class, old or new name.  Takes the
    caller's pltpu module so importing this shim never pulls in pallas."""
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
