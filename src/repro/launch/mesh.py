"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips as ("data", "model"); two pods = (2, 16, 16) as
("pod", "data", "model").  The "pod" axis carries only data parallelism +
FSDP — gradient all-reduces cross the (slow) inter-pod links once per step,
everything else stays intra-pod.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist locally (smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
