"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips as ("data", "model"); two pods = (2, 16, 16) as
("pod", "data", "model").  The "pod" axis carries only data parallelism +
FSDP — gradient all-reduces cross the (slow) inter-pod links once per step,
everything else stays intra-pod.
"""
from __future__ import annotations

import jax

from repro.compat import AxisType  # None when the installed jax lacks it


def mesh_axis_kwargs(n_axes: int) -> dict:
    """kwargs for jax.make_mesh that request Auto axes when the installed
    jax supports explicit axis types, and nothing otherwise."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist locally (smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         **mesh_axis_kwargs(2))
