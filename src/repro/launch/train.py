"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-every 10 --workdir /tmp/run1

Resumable: re-launching with the same --workdir restores the last committed
Nezha checkpoint manifest and continues bit-identically (restart-safe data
pipeline).  --crash-at simulates a host failure for drills.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--coordinator", action="store_true",
                    help="run the Raft control plane (step/ckpt commits)")
    args = ap.parse_args()

    from repro.configs import ShapeConfig, get
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.coordinator import Coordinator, TrainRunner

    cfg = get(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    mesh = make_host_mesh()
    coord = Coordinator(args.workdir) if args.coordinator else None
    runner = TrainRunner(cfg, shape, mesh, args.workdir, seed=args.seed,
                         ckpt_every=args.ckpt_every, coordinator=coord)
    start = runner.init_or_restore()
    print(f"[train] {cfg.name} starting at step {start} "
          f"(params={cfg.param_count() / 1e6:.1f}M)")
    t0 = time.time()
    losses = runner.run(args.steps, crash_at=args.crash_at)
    dt = time.time() - t0
    done = len(losses)
    if done:
        print(f"[train] {done} steps in {dt:.1f}s "
              f"({done * args.batch * args.seq / dt:.0f} tok/s) "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if coord is not None:
        print(f"[train] committed ckpts: {coord.committed_steps('ckpt')}")
        coord.destroy()


if __name__ == "__main__":
    main()
