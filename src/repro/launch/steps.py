"""Step factories: jitted, sharded train / prefill / decode steps.

Each factory closes over (cfg, mesh) and returns the jitted step plus the
ShapeDtypeStruct input specs used both by the dry-run (lower/compile with no
allocation) and by real execution (smoke tests, examples).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import forward, init_cache, init_params, lm_loss
from repro.sharding.rules import (Rules, cache_spec, make_rules, param_spec,
                                  tree_specs)
from repro.train.optimizer import OptHyper, adamw_update, init_opt_state

PyTree = Any


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok_dt = jnp.int32
    if shape.kind == "train":
        if cfg.input_kind == "embeds":
            tokens = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            tokens = jax.ShapeDtypeStruct((B, S), tok_dt)
        return {"tokens": tokens, "labels": jax.ShapeDtypeStruct((B, S), tok_dt)}
    if shape.kind == "prefill":
        if cfg.input_kind == "embeds":
            return {"tokens": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok_dt)}
    # decode: one new token against a seq_len-deep cache
    if cfg.input_kind == "embeds":
        tokens = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1), tok_dt)
    return {"tokens": tokens, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_shardings(cfg, shape, rules: Rules):
    specs = {}
    ins = input_specs(cfg, shape)
    for k, v in ins.items():
        if k == "pos":
            specs[k] = NamedSharding(rules.mesh, P())
        else:
            specs[k] = rules.sharding(v.shape, "batch")
    return specs


def abstract_state(cfg, key=jax.random.PRNGKey(0)):
    """Abstract (ShapeDtypeStruct) train state, never materialized."""
    def mk():
        params = init_params(key, cfg)
        m, v = init_opt_state(params)
        return {"params": params, "m": m, "v": v,
                "step": jnp.zeros((), jnp.int32)}
    return jax.eval_shape(mk)


def state_shardings(cfg, rules: Rules):
    st = abstract_state(cfg)
    return {
        "params": tree_specs(st["params"], param_spec, rules),
        "m": tree_specs(st["m"], param_spec, rules),
        "v": tree_specs(st["v"], param_spec, rules),
        "step": NamedSharding(rules.mesh, P()),
    }


def abstract_cache(cfg, shape: ShapeConfig, layout: str):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, layout))


def cache_shardings(cfg, shape, rules: Rules, layout: str):
    ac = abstract_cache(cfg, shape, layout)
    return tree_specs(ac, cache_spec, rules)


# -------------------------------------------------------------- train step
def make_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                    hyper: OptHyper = OptHyper()):
    rules = make_rules(mesh)

    def loss_of(params, tokens, labels):
        if cfg.loss_chunk:
            from repro.models.transformer import lm_loss_chunked
            hidden, _ = forward(params, tokens, cfg, rules, mode="train",
                                return_hidden=True)
            return lm_loss_chunked(params, hidden, labels, cfg, rules)
        logits, _ = forward(params, tokens, cfg, rules, mode="train")
        return lm_loss(logits, labels)

    p_specs = tree_specs(abstract_state(cfg)["params"], param_spec, rules)

    def shard_grads(grads):
        """Pin gradients to the parameter sharding.  Without this GSPMD
        emits per-layer f32 ALL-REDUCES of full weight gradients inside the
        backward scan (measured 4.6e12 B/dev on qwen2-72b); with it the sums
        lower to reduce-scatters into the (fsdp, model) layout."""
        if not cfg.grad_shard:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads,
            p_specs)

    def train_step(state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        accum = cfg.grad_accum
        if accum > 1:
            B = tokens.shape[0]
            tk = tokens.reshape((accum, B // accum) + tokens.shape[1:])
            lb = labels.reshape((accum, B // accum) + labels.shape[1:])

            def micro(carry, xs):
                t, l = xs
                loss, g = jax.value_and_grad(loss_of)(state["params"], t, l)
                g = shard_grads(g)
                carry = jax.tree.map(jnp.add, carry, (g, loss))
                return carry, ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), (tk, lb))
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        else:
            loss, grads = jax.value_and_grad(loss_of)(
                state["params"], tokens, labels)
            grads = shard_grads(grads)
        new_p, new_m, new_v, gnorm = adamw_update(
            state["params"], grads, state["m"], state["v"], state["step"],
            hyper)
        new_state = {"params": new_p, "m": new_m, "v": new_v,
                     "step": state["step"] + 1}
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_state, metrics

    st_sh = state_shardings(cfg, rules)
    b_sh = batch_shardings(cfg, shape, rules)
    rep = NamedSharding(mesh, P())
    step = jax.jit(train_step,
                   in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, {"loss": rep, "grad_norm": rep}),
                   donate_argnums=(0,))
    return step, rules, st_sh, b_sh


def make_init_fn(cfg, mesh):
    rules = make_rules(mesh)
    st_sh = state_shardings(cfg, rules)

    def init_fn(key):
        params = init_params(key, cfg)
        m, v = init_opt_state(params)
        return {"params": params, "m": m, "v": v,
                "step": jnp.zeros((), jnp.int32)}

    return jax.jit(init_fn, out_shardings=st_sh), st_sh


# ------------------------------------------------------------ prefill step
def make_prefill_step(cfg, mesh, shape: ShapeConfig, layout: str = "paged"):
    seqshard = shape.global_batch == 1
    rules = make_rules(mesh, seq_shard_cache=seqshard)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        cache0 = init_cache(cfg, B, shape.seq_len, layout)
        logits, cache = forward(params, tokens, cfg, rules, mode="prefill",
                                caches=cache0)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1)
        return next_tok, cache

    p_sh = tree_specs(abstract_state(cfg)["params"], param_spec, rules)
    b_sh = batch_shardings(cfg, shape, rules)
    c_sh = cache_shardings(cfg, shape, rules, layout)
    tok_out = rules.sharding((shape.global_batch, 1), "batch")
    step = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                   out_shardings=(tok_out, c_sh))
    return step, rules, p_sh, b_sh, c_sh


# ------------------------------------------------------------- decode step
def make_decode_step(cfg, mesh, shape: ShapeConfig, layout: str = "paged"):
    seqshard = shape.global_batch == 1
    rules = make_rules(mesh, seq_shard_cache=seqshard)

    def decode_step(params, cache, batch):
        tokens, pos = batch["tokens"], batch["pos"]
        logits, new_cache = forward(params, tokens, cfg, rules, mode="decode",
                                    caches=cache, pos=pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1)
        return next_tok, new_cache

    p_sh = tree_specs(abstract_state(cfg)["params"], param_spec, rules)
    b_sh = batch_shardings(cfg, shape, rules)
    c_sh = cache_shardings(cfg, shape, rules, layout)
    tok_out = rules.sharding((shape.global_batch, 1), "batch")
    step = jax.jit(decode_step, in_shardings=(p_sh, c_sh, b_sh),
                   out_shardings=(tok_out, c_sh), donate_argnums=(1,))
    return step, rules, p_sh, b_sh, c_sh
