"""Serving launcher: paged-KV continuous batching with Nezha cache GC.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --smoke \
      --requests 8 --max-new 12 --compact-every 4
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--compact-every", type=int, default=0,
                    help="run cache GC every N finished requests")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get
    from repro.serve.engine import ServingEngine

    cfg = get(args.arch, smoke=args.smoke)
    eng = ServingEngine(cfg, max_slots=args.slots, max_seq=args.max_seq,
                        seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        eng.submit(prompt, max_new=args.max_new)
    t0 = time.time()
    done = 0
    while eng.active or eng.queue:
        eng.step()
        newly = len(eng.finished) - done
        if newly and args.compact_every and \
                len(eng.finished) % args.compact_every == 0:
            frag = eng.fragmentation()
            eng.compact(backend="reference")
            print(f"[serve] cache GC: fragmentation {frag:.2f} -> "
                  f"{eng.fragmentation():.2f}")
        done = len(eng.finished)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in eng.finished)
    print(f"[serve] {len(eng.finished)} requests, {tokens} tokens in "
          f"{dt:.1f}s ({tokens / dt:.1f} tok/s), "
          f"{eng.decode_steps} decode steps, {eng.compactions} GCs")
    for r in eng.finished[:4]:
        print(f"  req{r.rid}: prompt={r.prompt} -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
