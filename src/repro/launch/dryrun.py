import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices exist; smoke tests and benchmarks see the real single device.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --all --layout dense

Results append to benchmarks/results/dryrun.json (idempotent per cell key) so
the full matrix can be built incrementally across invocations.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_is_skipped, get
from repro.launch import steps as steps_lib
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun.json"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               layout: str = "paged", variant: str = "base"):
    """Build + lower the step for one cell; returns (lowered, meta)."""
    cfg = get(arch)
    if variant != "base":
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ins = steps_lib.input_specs(cfg, shape)
    if shape.kind == "train":
        step, rules, st_sh, b_sh = steps_lib.make_train_step(cfg, mesh, shape)
        state = steps_lib.abstract_state(cfg)
        lowered = step.lower(state, ins)
    elif shape.kind == "prefill":
        step, rules, p_sh, b_sh, c_sh = steps_lib.make_prefill_step(
            cfg, mesh, shape, layout=layout)
        params = steps_lib.abstract_state(cfg)["params"]
        lowered = step.lower(params, ins)
    else:
        step, rules, p_sh, b_sh, c_sh = steps_lib.make_decode_step(
            cfg, mesh, shape, layout=layout)
        params = steps_lib.abstract_state(cfg)["params"]
        cache = steps_lib.abstract_cache(cfg, shape, layout)
        lowered = step.lower(params, cache, ins)
    return lowered, {"cfg": cfg, "shape": shape, "mesh": mesh}


def apply_variant(cfg, variant: str):
    """Named config tweaks used by the §Perf hillclimb."""
    mods = {
        "nosp": dict(seq_shard=False),
        "nogradshard": dict(grad_shard=False),
        "attnsp": dict(attn_seq_parallel=True),
        "accum1": dict(grad_accum=1),
        "losschunk": dict(loss_chunk=512),
        "remat_dots": dict(remat="dots"),
        "remat_none": dict(remat="none"),
        "accum2": dict(grad_accum=2),
        "accum4": dict(grad_accum=4),
        "accum8": dict(grad_accum=8),
        "blk16": dict(kv_block_size=16),
        "blk256": dict(kv_block_size=256),
    }
    out = cfg
    for part in variant.split("+"):
        if part == "base":
            continue
        out = out.replace(**mods[part])
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             layout: str = "paged", variant: str = "base") -> dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(cfg, shape)
    if skip:
        return {"status": "skipped", "reason": skip}
    n_dev = 512 if multi_pod else 256
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, layout, variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cost = analyze(compiled.as_text(), n_devices=n_dev)
    out = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "layout": layout, "variant": variant,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # memory_analysis is per device
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_live_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        # loop-corrected per-device analysis (see hlo_analysis.py)
        "per_device": {
            "flops": cost.flops,
            "hbm_bytes": cost.bytes,
            "collective_bytes": cost.coll_bytes,
            "collective_detail": cost.coll_detail,
        },
        # raw XLA numbers (loop bodies counted once) for cross-checking
        "xla_cost_analysis": {
            "flops": ca.get("flops", -1),
            "bytes_accessed": ca.get("bytes accessed", -1),
        },
        "model": {
            "params": meta["cfg"].param_count(),
            "active_params": meta["cfg"].active_param_count(),
        },
    }
    return out


def cell_key(arch, shape_name, multi_pod, layout, variant):
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}|{shape_name}|{mesh}|{layout}|{variant}"


def save_result(key: str, result: dict):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if RESULTS.exists():
        data = json.loads(RESULTS.read_text())
    data[key] = result
    RESULTS.write_text(json.dumps(data, indent=1, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--layout", default="paged", choices=["paged", "dense"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results file")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    existing = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}
    n_ok = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                key = cell_key(arch, shape_name, multi_pod, args.layout,
                               args.variant)
                if not args.force and existing.get(key, {}).get("status") \
                        in ("ok", "skipped"):
                    print(f"[cached] {key}")
                    continue
                print(f"[run]    {key}", flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod, args.layout,
                                   args.variant)
                    n_ok += 1
                except Exception as e:  # record failures: they are bugs
                    res = {"status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                    print(f"  ERROR {e!r}", flush=True)
                save_result(key, res)
                if res.get("status") == "ok":
                    pd = res["per_device"]
                    print(f"  ok lower={res['lower_s']}s "
                          f"compile={res['compile_s']}s "
                          f"flops/dev={pd['flops']:.3e} "
                          f"hbm/dev={pd['hbm_bytes']:.3e} "
                          f"coll/dev={pd['collective_bytes']:.3e}", flush=True)
    print(f"done ok={n_ok} fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
