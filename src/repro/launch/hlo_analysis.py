"""Loop-aware cost analysis over optimized (post-SPMD-partitioning) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body exactly once, which
under-reports every scanned-layer model by ~n_layers.  This analyzer walks the
HLO module, multiplies loop bodies by their static trip counts (parsed from
the loop-condition constant), recurses through fusions/calls/conditionals,
and reports per-device:

  * flops            — 2*M*N*K for every ``dot`` (batch dims included)
  * bytes            — HBM traffic estimate: operand+result bytes at fusion
                       granularity (XLA's own 'bytes accessed' convention)
  * collective_bytes — wire bytes per chip with ring-algorithm factors:
        all-gather      out*(n-1)/n      all-reduce  2*out*(n-1)/n
        reduce-scatter  in*(n-1)/n       all-to-all  in*(n-1)/n
        collective-permute  out
  * per-collective breakdown for the §Perf iteration log.

The module text is the per-device partitioned program, so every number is
already per-chip.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(DTYPE_BYTES) + r")\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all"}
_ZERO_COST = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "after-all", "partition-id", "replica-id",
              "all-reduce-done", "all-gather-done", "collective-permute-done",
              "opt-barrier"}


def type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * DTYPE_BYTES[dt]
    return total


def type_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(x) for x in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # args + attrs tail of the line


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_detail.items():
            self.coll_detail[k] = self.coll_detail.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {n: v * k for n, v in self.coll_detail.items()})


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        cur: Optional[List[Op]] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and not line.lstrip().startswith("%param"):
                name = mc.group(2)
                cur = []
                self.comps[name] = cur
                if mc.group(1):
                    self.entry = name
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mo = _OP_RE.match(line)
            if mo:
                cur.append(Op(mo.group(1), mo.group(2), mo.group(3),
                              mo.group(4)))
        self._defs: Dict[str, Dict[str, str]] = {
            cname: {op.name: op.result_type for op in ops}
            for cname, ops in self.comps.items()}
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------- helpers
    def _operands(self, op: Op) -> List[str]:
        depth, args = 0, ""
        for ch in op.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            args += ch
        return re.findall(r"%([\w.\-]+)", args)

    def _operand_bytes(self, cname: str, op: Op) -> float:
        defs = self._defs[cname]
        return sum(type_bytes(defs[o]) for o in self._operands(op)
                   if o in defs)

    def trip_count(self, cond_name: str) -> int:
        consts = [int(c) for op in self.comps.get(cond_name, ())
                  for c in _CONST_RE.findall(op.result_type + " " +
                                             op.opcode + "(" + op.rest)]
        return max(consts) if consts else 1

    def _dot_flops(self, cname: str, op: Op) -> float:
        out_elems = 1
        for d in type_dims(op.result_type):
            out_elems *= d
        operands = self._operands(op)
        lhs_dims = type_dims(self._defs[cname].get(operands[0], "")) \
            if operands else []
        mcon = _CONTRACT_RE.search(op.rest)
        contract = 1
        if mcon and lhs_dims:
            for i in [int(x) for x in mcon.group(1).split(",") if x]:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        return 2.0 * out_elems * contract

    def _group_size(self, op: Op, default: int) -> int:
        m = _GROUPS_LIST_RE.search(op.rest)
        if m:
            return len(m.group(1).split(","))
        m = _GROUPS_IOTA_RE.search(op.rest)
        if m:
            return int(m.group(2))
        return default

    def _collective_bytes(self, cname: str, op: Op, n_devices: int) -> float:
        n = max(self._group_size(op, n_devices), 1)
        out_b = type_bytes(op.result_type)
        in_b = self._operand_bytes(cname, op)
        kind = op.opcode.replace("-start", "")
        if kind == "all-gather":
            return out_b * (n - 1) / n
        if kind == "all-reduce":
            return 2.0 * out_b * (n - 1) / n
        if kind == "reduce-scatter":
            return in_b * (n - 1) / n
        if kind in ("all-to-all", "ragged-all-to-all"):
            return in_b * (n - 1) / n
        if kind == "collective-permute":
            return out_b
        return out_b

    # ---------------------------------------------------------------- cost
    def comp_cost(self, cname: str, n_devices: int = 1) -> Cost:
        if cname in self._memo:
            return self._memo[cname]
        total = Cost()
        self._memo[cname] = total  # breaks (non-existent) cycles
        for op in self.comps.get(cname, ()):
            oc = op.opcode
            if oc in _ZERO_COST:
                continue
            if oc == "fusion":
                m = _CALLS_RE.search(op.rest)
                if m:
                    sub = self.comp_cost(m.group(1), n_devices)
                    total.flops += sub.flops
                    total.coll_bytes += sub.coll_bytes
                total.bytes += self._operand_bytes(cname, op) + \
                    type_bytes(op.result_type)
            elif oc == "while":
                mb, mc = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                trip = self.trip_count(mc.group(1)) if mc else 1
                if mb:
                    total += self.comp_cost(mb.group(1), n_devices).scaled(trip)
                if mc:
                    total += self.comp_cost(mc.group(1), n_devices).scaled(trip)
            elif oc == "conditional":
                branches = []
                m = _BRANCH_RE.search(op.rest)
                if m:
                    branches = re.findall(r"%?([\w.\-]+)", m.group(1))
                else:
                    branches = _TF_RE.findall(op.rest)
                if branches:
                    costs = [self.comp_cost(b, n_devices) for b in branches]
                    worst = max(costs, key=lambda c: c.flops + c.bytes)
                    total += worst
            elif oc in ("call", "async-start", "custom-call"):
                m = _CALLS_RE.search(op.rest)
                if m:
                    total += self.comp_cost(m.group(1), n_devices)
                total.bytes += self._operand_bytes(cname, op) + \
                    type_bytes(op.result_type)
            else:
                total.bytes += self._operand_bytes(cname, op) + \
                    type_bytes(op.result_type)
                if oc == "dot":
                    total.flops += self._dot_flops(cname, op)
                elif oc in COLLECTIVES:
                    b = self._collective_bytes(cname, op, n_devices)
                    total.coll_bytes += b
                    key = oc.replace("-start", "")
                    total.coll_detail[key] = \
                        total.coll_detail.get(key, 0.0) + b
        self._memo[cname] = total
        return total

    def entry_cost(self, n_devices: int = 1) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry, n_devices)


def analyze(hlo_text: str, n_devices: int = 1) -> Cost:
    return HloModule(hlo_text).entry_cost(n_devices)
