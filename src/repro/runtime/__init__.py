from repro.runtime.coordinator import Coordinator, TrainRunner  # noqa: F401
