"""Fault-tolerant training runtime on the Nezha control plane.

The Raft cluster (KVS-Raft engines) is the control plane: step commits,
checkpoint commits, heartbeats, and membership changes are LIGHTWEIGHT log
entries (the paper's key insight applied to training: bulky state — tensors —
never crosses consensus; it is appended once to host-local ValueLogs and only
the manifest is replicated).

Fault model on a real fleet: each host runs this coordinator client; the
Raft quorum lives on a small set of controller nodes.  Here the cluster is
in-process (deterministic), which is exactly what the integration tests need:
  * crash at step k -> restore from last committed ckpt -> loss curve is
    bit-identical to the uninterrupted run (restart-safe data pipeline);
  * straggler detection via heartbeat records;
  * elastic rescale: the committed manifest is mesh-agnostic (named tensors),
    so a restore can target a different mesh/sharding.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt.nezha_store import NezhaCheckpointStore
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import Cluster
from repro.data.pipeline import TokenPipeline
from repro.launch import steps as steps_lib


class Coordinator:
    """Thin client over the Raft control plane."""

    def __init__(self, workdir: str, n_controllers: int = 3, seed: int = 0,
                 straggler_factor: float = 3.0):
        self.cluster = Cluster(n=n_controllers, engine="nezha",
                               workdir=f"{workdir}/control", seed=seed,
                               engine_kwargs={"gc_threshold": 8 << 20})
        self.cluster.elect()
        self.straggler_factor = straggler_factor
        self._hb: Dict[int, float] = {}
        self._step_times: List[float] = []

    def commit(self, kind: str, payload: dict):
        key = f"{kind}/{payload.get('step', 0):012d}".encode()
        self.cluster.put(key, json.dumps(payload).encode())

    def committed_steps(self, kind: str = "step") -> List[int]:
        rows = self.cluster.scan(f"{kind}/".encode(), f"{kind}/~".encode())
        return [json.loads(v)["step"] for _, v in rows]

    def heartbeat(self, host_id: int, step: int, wall: float):
        self._hb[host_id] = wall
        self._step_times.append(wall)

    def stragglers(self, now: float, hosts: List[int]) -> List[int]:
        """Hosts whose last heartbeat lags median step time by `factor`x."""
        if len(self._step_times) < 4:
            return []
        recent = self._step_times[-16:]
        typical = float(np.median(np.diff(recent))) if len(recent) > 1 else 0
        if typical <= 0:
            return []
        return [h for h in hosts
                if now - self._hb.get(h, now) > self.straggler_factor *
                typical]

    def membership_change(self, payload: dict):
        self.commit("member", payload)

    def destroy(self):
        self.cluster.destroy()


class TrainRunner:
    """End-to-end driver: data -> train_step -> Nezha ckpt -> raft commits."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 workdir: str, seed: int = 0, ckpt_every: int = 10,
                 coordinator: Optional[Coordinator] = None, keep: int = 2):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.workdir = workdir
        self.seed = seed
        self.ckpt_every = ckpt_every
        self.coord = coordinator
        self.step_fn, self.rules, self.st_sh, self.b_sh = \
            steps_lib.make_train_step(cfg, mesh, shape)
        self.init_fn, _ = steps_lib.make_init_fn(cfg, mesh)
        self.store = NezhaCheckpointStore(
            f"{workdir}/ckpt", keep=keep,
            cluster=coordinator.cluster if coordinator else None)
        self.state = None
        self.start_step = 0

    def init_or_restore(self):
        latest = self.store.latest_step()
        if latest is None:
            self.state = self.init_fn(jax.random.PRNGKey(self.seed))
            self.start_step = 0
        else:
            template = jax.eval_shape(
                lambda: steps_lib.abstract_state(self.cfg))
            host_tree, step = self.store.restore(
                steps_lib.abstract_state(self.cfg))
            self.state = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh),
                host_tree, self.st_sh)
            self.start_step = step
        return self.start_step

    def _put_batch(self, batch):
        return {k: jax.device_put(v, self.b_sh[k])
                for k, v in batch.items()}

    def run(self, n_steps: int, crash_at: Optional[int] = None) -> List[float]:
        """Returns per-step losses. crash_at simulates a host failure by
        raising after that step commits (state is NOT checkpointed then
        unless on the ckpt_every boundary — restart resumes from the last
        committed manifest)."""
        pipe = TokenPipeline(self.cfg, self.shape, seed=self.seed,
                             start_step=self.start_step)
        losses = []
        try:
            for step in range(self.start_step, n_steps):
                batch = self._put_batch(pipe.batch_for_step(step))
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                if self.coord is not None:
                    self.coord.commit("step", {"step": step, "loss": loss})
                    self.coord.heartbeat(0, step, time.time())
                if (step + 1) % self.ckpt_every == 0:
                    host_state = jax.tree.map(np.asarray, self.state)
                    self.store.save(step + 1, host_state)
                    if self.coord is not None:
                        self.coord.commit("ckpt", {"step": step + 1})
                if crash_at is not None and step + 1 == crash_at:
                    raise RuntimeError(f"injected host failure at {crash_at}")
        finally:
            pipe.close()
        return losses
