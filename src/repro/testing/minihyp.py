"""Deterministic fallback for the `hypothesis` API surface our tests use.

The container image does not ship hypothesis; rather than skip the Raft
safety properties (they are the paper's §III-E verification analogue) we
replay each @given test over `max_examples` seeded pseudo-random draws.
Strictly weaker than real hypothesis (no shrinking, no coverage guidance)
but the fault schedules are reproducible and genuinely adversarial.

Only the strategies used in tests/ are implemented:
  integers, sampled_from, one_of, tuples, just, lists, binary.
"""
from __future__ import annotations

import enum
import functools
import inspect
import random
from typing import Any, Callable, List


class HealthCheck(enum.Enum):
    data_too_large = 1
    filter_too_much = 2
    too_slow = 3
    function_scoped_fixture = 4


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    @staticmethod
    def one_of(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda r: r.choice(strats).example(r))

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda r: tuple(s.example(r) for s in strats))

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy(lambda r: value)

    @staticmethod
    def lists(strat: _Strategy, min_size: int = 0,
              max_size: int = 16) -> _Strategy:
        def draw(r: random.Random) -> List[Any]:
            n = r.randint(min_size, max_size)
            return [strat.example(r) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def binary(min_size: int = 0, max_size: int = 16) -> _Strategy:
        def draw(r: random.Random) -> bytes:
            n = r.randint(min_size, max_size)
            return bytes(r.getrandbits(8) for _ in range(n))
        return _Strategy(draw)


def given(*strat_args: _Strategy, **strat_kwargs: _Strategy):
    def deco(fn):
        # like real hypothesis, positional strategies bind to the RIGHTMOST
        # parameters (leading params stay free for pytest fixtures)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        pos_names = [p.name for p in params[len(params) - len(strat_args):]] \
            if strat_args else []
        strategies_by_name = dict(zip(pos_names, strat_args))
        strategies_by_name.update(strat_kwargs)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_minihyp_max_examples", 10)
            for i in range(n):
                rng = random.Random(0xC0FFEE + i * 101)
                drawn = {k: s.example(rng)
                         for k, s in strategies_by_name.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"minihyp example {i}/{n} failed with inputs "
                        f"{drawn!r}") from e
        if not hasattr(wrapper, "_minihyp_max_examples"):
            # functools.wraps already copied the attr when @settings sits
            # below @given; only default when no settings were applied
            wrapper._minihyp_max_examples = 10
        # hide strategy-filled params from pytest's fixture resolution
        remaining = [p for p in params if p.name not in strategies_by_name]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco


def settings(max_examples: int = 10, deadline=None,
             suppress_health_check=None, **_ignored):
    def deco(fn):
        fn._minihyp_max_examples = max_examples
        return fn
    return deco
